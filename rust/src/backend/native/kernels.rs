//! Sparse×dense FC kernels, softmax cross-entropy, and the SGD-momentum
//! update — the native engine's math, as free functions over slices so
//! every kernel is unit-testable against a dense oracle.
//!
//! Layout conventions (all row-major):
//! * activations `x`/`y`/`dy` are `(batch × dim)`;
//! * an FC weight tensor is `(in_dim × out_dim)`, flat index
//!   `i·out_dim + o`, with its sparsity structure in a [`CsrTopo`]
//!   (values stay in the dense tensor — see `csr` module docs);
//! * gradient values for sparse weights are accumulated *positionally*,
//!   parallel to `CsrTopo::col_idx`, so backward cost is O(nnz·batch)
//!   like the forward.
//!
//! ## Batch-panel SIMD
//!
//! The hot kernels execute in **batch-major micro-panels** of
//! [`LANES`] (8) batch elements: activations are transposed into
//! panel-major lane vectors ([`simd::PanelScratch`]) so ONE walk of a
//! CSR row's index/value stream feeds eight accumulations at once,
//! instead of re-walking the topology per batch element. Lanes always
//! map to *distinct output elements* (batch columns for the forwards
//! and `dx`; consecutive entries / output columns for `dw`; batch rows
//! for softmax), and every per-element accumulation keeps the flat
//! loop's term order — including the zero-activation skip, applied per
//! lane as a branch-free select ([`F32Lanes::fma_nz`]) — so panel
//! results are **bit-identical** to the scalar loops by construction.
//! Ragged tails (batch % 8 rows, nnz % 8 entries, out_dim % 8 columns)
//! fall back to the scalar loop, which lives in [`reference`] and
//! doubles as the oracle `tests/simd_determinism.rs` compares against.
//! [`set_panel_kernels`] switches panels off globally (the benches'
//! `lanes=1` grid dimension); it is a wall-clock knob, never a
//! correctness knob.
//!
//! ## Parallel execution and the determinism contract
//!
//! Every hot kernel takes an [`Exec`]: `Exec::Serial` runs on the
//! caller's thread, `Exec::Pool` dispatches block work units onto a
//! shared [`KernelPool`]. Results are **bit-identical** between the two
//! — and across any thread count, block layout, or lane width — because
//! the decomposition never reorders a floating-point reduction:
//!
//! * work units partition the OUTPUT (column blocks × batch panels for
//!   the forwards, row blocks × batch panels for `dx`, row blocks for
//!   the weight products and the optimizer step, batch panels for
//!   softmax), so no two units touch the same element;
//! * within a unit, each output element's accumulation runs in exactly
//!   the flat loop's order (increasing input row for `y[c] +=`,
//!   increasing batch row for `dw[k] +=` — which is why the `dw`
//!   kernels vectorize over *entries*, never across the batch);
//! * the one cross-unit reduction — the scalar loss — is a serial sum
//!   of per-row losses in batch order, the same sequence the flat loop
//!   produces.
//!
//! Tiny layers stay flat: each pool carries a `par_min_ops` floor
//! measured from its own fork-join round-trip cost at construction
//! (see [`KernelPool::par_min_ops`]), so LeNet-scale heads and small
//! batches never pay the ~µs round. The gate is free to differ per call
//! or per machine — flat, blocked and panel paths are bitwise
//! interchangeable. See `backend/native/README.md`.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::pool::KernelPool;

use super::csr::CsrTopo;
use super::simd::{pack_panels, F32Lanes, PanelScratch, LANES};

/// Execution context for the kernels: serial, or fork-join work-unit
/// dispatch on a shared [`KernelPool`].
#[derive(Clone, Copy)]
pub enum Exec<'p> {
    Serial,
    Pool(&'p KernelPool),
}

impl<'p> Exec<'p> {
    /// Threads this context can bring to bear (1 for serial).
    pub fn threads(&self) -> usize {
        match self {
            Exec::Serial => 1,
            Exec::Pool(p) => p.threads(),
        }
    }

    /// The pool, if parallel execution is worthwhile for a kernel doing
    /// `ops` inner-loop operations — the autotune gate (measured per
    /// pool at construction) that keeps tiny layers on the flat path.
    fn pool_for(&self, ops: usize) -> Option<&'p KernelPool> {
        match *self {
            Exec::Pool(p) if p.threads() > 1 && ops >= p.par_min_ops() => Some(p),
            _ => None,
        }
    }
}

/// Global switch for the batch-panel SIMD paths (default ON). The
/// benches flip it to record the `lanes=1` dimension of their grids and
/// the determinism suite uses it to prove whole training runs are
/// bit-identical either way.
static PANEL_KERNELS: AtomicBool = AtomicBool::new(true);

/// Enable/disable the panel paths globally; returns the previous
/// setting. Purely a wall-clock knob — results are bit-identical at
/// either setting.
pub fn set_panel_kernels(on: bool) -> bool {
    PANEL_KERNELS.swap(on, Ordering::Relaxed)
}

/// Whether the panel paths are currently enabled.
pub fn panel_kernels() -> bool {
    PANEL_KERNELS.load(Ordering::Relaxed)
}

/// A batch qualifies for panel execution when it holds at least one
/// full panel (the tail past `batch/LANES` panels runs flat).
#[inline(always)]
fn use_panels(batch: usize) -> bool {
    batch >= LANES && panel_kernels()
}

/// Run `task(t)` for `t in 0..n_tasks` across the pool's lanes, load-
/// balanced by an atomic cursor. Tasks must write disjoint output
/// regions; since every per-element accumulation keeps the serial
/// order, ANY task-to-lane assignment is bit-identical, so dynamic
/// balancing costs nothing determinism-wise.
fn dispatch(pool: &KernelPool, n_tasks: usize, task: &(dyn Fn(usize) + Sync)) {
    use std::sync::atomic::AtomicUsize;
    let cursor = AtomicUsize::new(0);
    pool.fork_join(&|_lane| loop {
        let t = cursor.fetch_add(1, Ordering::Relaxed);
        if t >= n_tasks {
            break;
        }
        task(t);
    });
}

/// Raw mutable base pointer shared across tasks that write DISJOINT
/// regions of one output slice.
///
/// SAFETY contract (upheld by every use in this module): each task
/// derives a sub-slice no other task overlaps, and `dispatch` joins all
/// lanes before the kernel returns, so no derived reference outlives
/// the `&mut` borrow that produced the pointer and no two regions
/// alias. Serial callers reuse the same helpers with a single "task"
/// owning everything.
#[derive(Clone, Copy)]
struct MutPtr<T>(*mut T);
unsafe impl<T> Send for MutPtr<T> {}
unsafe impl<T> Sync for MutPtr<T> {}

/// Where a forward kernel reads its weight values: the dense tensor
/// (training, structure-only CSR) or the packed value array (serving,
/// value-carrying CSR). Monomorphized, so both forwards compile to the
/// same loop with only the load differing — which is what makes their
/// outputs bit-identical on equal weights.
trait WSource: Sync {
    fn val(&self, k: usize, wrow: usize, c: usize) -> f32;
}

struct DenseW<'a>(&'a [f32]);
impl WSource for DenseW<'_> {
    #[inline(always)]
    fn val(&self, _k: usize, wrow: usize, c: usize) -> f32 {
        self.0[wrow + c]
    }
}

struct CsrVals<'a>(&'a [f32]);
impl WSource for CsrVals<'_> {
    #[inline(always)]
    fn val(&self, k: usize, _wrow: usize, _c: usize) -> f32 {
        self.0[k]
    }
}

/// Entry range of row `i` restricted to column block `blk` (`None` =
/// the whole row).
#[inline(always)]
fn entry_range(topo: &CsrTopo, i: usize, blk: Option<usize>) -> (usize, usize) {
    match blk {
        Some(j) => topo.cb_range(i, j),
        None => (topo.row_ptr[i] as usize, topo.row_ptr[i + 1] as usize),
    }
}

// ---------------------------------------------------------------------
// Forward
// ---------------------------------------------------------------------

/// Forward: `y = x·W + bias` with `W` sparse (values read from the
/// dense tensor). `y` is fully overwritten. `scratch` holds the batch-
/// panel transposes (allocation-free once warm).
#[allow(clippy::too_many_arguments)]
pub fn spmm_bias_fwd(
    exec: Exec,
    x: &[f32],
    batch: usize,
    topo: &CsrTopo,
    w: &[f32],
    bias: &[f32],
    y: &mut [f32],
    scratch: &mut PanelScratch,
) {
    crate::obs_counter!("kernels.spmm_bias_fwd").inc();
    spmm_fwd_impl(exec, x, batch, topo, &DenseW(w), bias, y, scratch);
}

/// Forward `y = x·W + bias` with `W` as a value-carrying CSR: `vals` is
/// positionally parallel to `topo.col_idx`, so no dense weight tensor
/// exists at all — the frozen serve artifact format (`serve::artifact`).
/// Iteration order is identical to [`spmm_bias_fwd`], so logits are
/// bit-identical to the training engine's forward on the same weights,
/// and each batch row's accumulation is independent — batched execution
/// is bit-identical to batch=1 (the micro-batcher's correctness
/// contract).
#[allow(clippy::too_many_arguments)]
pub fn csr_spmm_bias_fwd(
    exec: Exec,
    x: &[f32],
    batch: usize,
    topo: &CsrTopo,
    vals: &[f32],
    bias: &[f32],
    y: &mut [f32],
    scratch: &mut PanelScratch,
) {
    debug_assert_eq!(vals.len(), topo.nnz());
    crate::obs_counter!("kernels.csr_spmm_bias_fwd").inc();
    spmm_fwd_impl(exec, x, batch, topo, &CsrVals(vals), bias, y, scratch);
}

/// Shared forward body. Output partition: COLUMN blocks × batch panels
/// — each work unit owns output columns `[c0, c1)` of one panel's (or
/// the batch tail's) rows, so `y[c] +=` accumulations stay within one
/// unit and run in increasing input-row order exactly like the flat
/// loop.
#[allow(clippy::too_many_arguments)]
fn spmm_fwd_impl<S: WSource>(
    exec: Exec,
    x: &[f32],
    batch: usize,
    topo: &CsrTopo,
    src: &S,
    bias: &[f32],
    y: &mut [f32],
    scratch: &mut PanelScratch,
) {
    let (ind, outd) = (topo.rows, topo.cols);
    debug_assert_eq!(x.len(), batch * ind);
    debug_assert_eq!(y.len(), batch * outd);
    debug_assert_eq!(bias.len(), outd);
    let ncb = topo.blocks.n_col_blocks();
    let pool = exec.pool_for(batch * topo.nnz().max(outd));
    let yp = MutPtr(y.as_mut_ptr());
    if use_panels(batch) {
        let npanels = batch / LANES;
        let tail = npanels * LANES;
        let (xp, yacc) = scratch.xy_bufs(npanels * ind, npanels * outd);
        pack_panels(x, ind, npanels, xp);
        let xp: &[F32Lanes] = xp;
        let units = npanels + (tail < batch) as usize;
        match pool {
            // Panels are a work-unit axis of their own: dispatch when
            // EITHER axis offers parallelism, so single-column-block
            // (or block-less) layers still scale across batch panels.
            Some(pool) if ncb > 1 || units > 1 => {
                let ncb_eff = ncb.max(1);
                let ap = MutPtr(yacc.as_mut_ptr());
                dispatch(pool, units * ncb_eff, &|t| {
                    let (u, j) = (t / ncb_eff, t % ncb_eff);
                    let (c0, c1, blk) = if ncb > 1 {
                        (
                            topo.blocks.col_blk[j] as usize,
                            topo.blocks.col_blk[j + 1] as usize,
                            Some(j),
                        )
                    } else {
                        (0, outd, None)
                    };
                    if u < npanels {
                        // SAFETY: accumulator lanes [u·outd+c0, u·outd+c1)
                        // — owned by task (u, j) alone (MutPtr contract).
                        let acc = unsafe {
                            std::slice::from_raw_parts_mut(ap.0.add(u * outd + c0), c1 - c0)
                        };
                        fwd_panel(
                            &xp[u * ind..(u + 1) * ind],
                            u * LANES,
                            topo,
                            src,
                            bias,
                            c0,
                            c1,
                            blk,
                            acc,
                            yp,
                            outd,
                        );
                    } else {
                        fwd_flat_cols(x, tail, batch, topo, src, bias, c0, c1, blk, yp);
                    }
                });
            }
            _ => {
                for p in 0..npanels {
                    fwd_panel(
                        &xp[p * ind..(p + 1) * ind],
                        p * LANES,
                        topo,
                        src,
                        bias,
                        0,
                        outd,
                        None,
                        &mut yacc[p * outd..(p + 1) * outd],
                        yp,
                        outd,
                    );
                }
                fwd_flat_cols(x, tail, batch, topo, src, bias, 0, outd, None, yp);
            }
        }
    } else {
        match pool {
            Some(pool) if ncb > 1 => {
                dispatch(pool, ncb, &|j| {
                    let c0 = topo.blocks.col_blk[j] as usize;
                    let c1 = topo.blocks.col_blk[j + 1] as usize;
                    fwd_flat_cols(x, 0, batch, topo, src, bias, c0, c1, Some(j), yp);
                });
            }
            _ => fwd_flat_cols(x, 0, batch, topo, src, bias, 0, outd, None, yp),
        }
    }
}

/// One batch panel × one column range of the forward: accumulate the
/// panel's eight rows in lane vectors, then scatter into the row-major
/// output. Per output element the term order is exactly the flat
/// loop's: increasing input row, with the zero-activation skip applied
/// per lane by the `fma_nz` select.
#[allow(clippy::too_many_arguments)]
fn fwd_panel<S: WSource>(
    xp: &[F32Lanes],
    b0: usize,
    topo: &CsrTopo,
    src: &S,
    bias: &[f32],
    c0: usize,
    c1: usize,
    blk: Option<usize>,
    yacc: &mut [F32Lanes],
    y: MutPtr<f32>,
    outd: usize,
) {
    for (c, acc) in (c0..c1).zip(yacc.iter_mut()) {
        *acc = F32Lanes::splat(bias[c]);
    }
    for (i, xl) in xp.iter().enumerate() {
        if !xl.any_nonzero() {
            continue; // every lane would skip row i: adds no terms
        }
        let wrow = i * outd;
        let (ks, ke) = entry_range(topo, i, blk);
        for k in ks..ke {
            let c = topo.col_idx[k] as usize;
            yacc[c - c0] = yacc[c - c0].fma_nz(*xl, src.val(k, wrow, c));
        }
    }
    for l in 0..LANES {
        // SAFETY: columns [c0, c1) of batch row b0+l — this task's panel
        // and column range alone (MutPtr contract).
        let row = unsafe { std::slice::from_raw_parts_mut(y.0.add((b0 + l) * outd + c0), c1 - c0) };
        for (slot, acc) in row.iter_mut().zip(yacc.iter()) {
            *slot = acc.0[l];
        }
    }
}

/// Flat scalar forward over batch rows `[b0, b1)` restricted to output
/// columns `[c0, c1)` — the ragged-tail path and the `reference` body.
#[allow(clippy::too_many_arguments)]
fn fwd_flat_cols<S: WSource>(
    x: &[f32],
    b0: usize,
    b1: usize,
    topo: &CsrTopo,
    src: &S,
    bias: &[f32],
    c0: usize,
    c1: usize,
    blk: Option<usize>,
    y: MutPtr<f32>,
) {
    let (ind, outd) = (topo.rows, topo.cols);
    for b in b0..b1 {
        let xrow = &x[b * ind..(b + 1) * ind];
        // SAFETY: columns [c0, c1) of batch row b — callers hand each
        // (row-range, column-range) region to exactly one task (MutPtr
        // contract).
        let yreg = unsafe { std::slice::from_raw_parts_mut(y.0.add(b * outd + c0), c1 - c0) };
        yreg.copy_from_slice(&bias[c0..c1]);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = i * outd;
            let (ks, ke) = entry_range(topo, i, blk);
            for k in ks..ke {
                let c = topo.col_idx[k] as usize;
                yreg[c - c0] += xv * src.val(k, wrow, c);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Packed (decode-on-the-fly) forward — RIGLSRVD v2
// ---------------------------------------------------------------------

/// Borrowed view of one packed (RIGLSRVD v2) layer's weight streams —
/// what `serve::artifact::PackedWeights` lends the kernels. The
/// topology's `col_idx` is EMPTY for a packed layer: indices live in
/// `idx` as per-(row, column-block) varint delta chains (byte-level
/// spec in `docs/FORMATS.md`) and are decoded into `PanelScratch`
/// staging one sub-range at a time, just ahead of the inner loop.
pub struct PackedFwd<'a> {
    /// The varint index stream, verbatim from disk (counts + deltas).
    pub idx: &'a [u8],
    /// Byte offset of each sub-range's FIRST DELTA (past its count
    /// varint), row-major `rows × max(ncb, 1)`. Built once at load.
    pub cb_byte: &'a [u32],
    /// Largest per-row entry count — bounds every staging region.
    pub max_row: usize,
    /// Values in entry order (f32 verbatim, or f16 widened per decode).
    pub vals: PackedValsRef<'a>,
}

/// The two value encodings a packed layer can carry.
#[derive(Clone, Copy)]
pub enum PackedValsRef<'a> {
    F32(&'a [f32]),
    F16(&'a [u16]),
}

impl<'a> PackedValsRef<'a> {
    /// The `n` values at entry offset `ks` as f32: a zero-copy slice on
    /// the f32 path (bit-identical to the plain forward by
    /// construction), a widening copy through `stage` on the f16 path
    /// (one rounding per weight at ENCODE time; widening is exact).
    #[inline(always)]
    fn widen<'s>(&self, ks: usize, n: usize, stage: &'s mut [f32]) -> &'s [f32]
    where
        'a: 's,
    {
        match *self {
            PackedValsRef::F32(v) => &v[ks..ks + n],
            PackedValsRef::F16(h) => {
                for (s, &b) in stage[..n].iter_mut().zip(&h[ks..ks + n]) {
                    *s = crate::util::f16_bits_to_f32(b);
                }
                &stage[..n]
            }
        }
    }
}

/// Decode the column indices of sub-range `(i, j)` — `n` entries — into
/// `out`. The first delta is from the block's base column, the rest are
/// strictly-positive gaps, so a running sum reproduces the sorted
/// indices. The stream was exhaustively validated at load; a decode
/// failure here is unreachable.
#[inline(always)]
fn decode_sub(pw: &PackedFwd, topo: &CsrTopo, i: usize, j: usize, ncb: usize, n: usize, out: &mut [u32]) -> usize {
    if n == 0 {
        return 0;
    }
    let mut pos = pw.cb_byte[i * ncb + j] as usize;
    let mut c = topo.blocks.col_blk[j];
    for slot in out[..n].iter_mut() {
        c += crate::util::uvarint_decode(pw.idx, &mut pos).expect("validated v2 index stream");
        *slot = c;
    }
    n
}

/// Decode row `i` restricted to column block `blk` (`None` = the whole
/// row, concatenating every sub-range's chain). Returns `(ks, n)`: the
/// row/block's entry offset (for the value stream) and entry count.
/// `out` must hold `PackedFwd::max_row` entries.
#[inline]
fn decode_row(pw: &PackedFwd, topo: &CsrTopo, i: usize, blk: Option<usize>, out: &mut [u32]) -> (usize, usize) {
    let ncb = topo.blocks.n_col_blocks().max(1);
    match blk {
        Some(j) => {
            let (ks, ke) = topo.cb_range(i, j);
            (ks, decode_sub(pw, topo, i, j, ncb, ke - ks, out))
        }
        None => {
            let ks = topo.row_ptr[i] as usize;
            let ke = topo.row_ptr[i + 1] as usize;
            if ncb == 1 {
                return (ks, decode_sub(pw, topo, i, 0, 1, ke - ks, out));
            }
            let mut n = 0usize;
            for j in 0..ncb {
                let (s, e) = topo.cb_range(i, j);
                n += decode_sub(pw, topo, i, j, ncb, e - s, &mut out[n..]);
            }
            debug_assert_eq!(n, ke - ks);
            (ks, n)
        }
    }
}

/// Forward `y = x·W + bias` with `W` PACKED (RIGLSRVD v2): the hot loop
/// streams ~3 bytes/nnz (varint index deltas + f16 values) instead of
/// the plain path's 8, decoding each (row, column-block) sub-range into
/// per-task `scratch` staging right before the same lane-8 / flat inner
/// loops [`csr_spmm_bias_fwd`] runs. Work-unit partition, term order and
/// the zero-activation skip are identical, so f32-valued packed logits
/// are bit-identical to the plain forward at any threads × blocks ×
/// lanes. f16 values are widened to f32 (exactly) and accumulated in
/// f32 — still deterministic, but each weight was rounded once at
/// export; the serve tests gate that path by epsilon + top-1 agreement.
#[allow(clippy::too_many_arguments)]
pub fn packed_spmm_bias_fwd(
    exec: Exec,
    x: &[f32],
    batch: usize,
    topo: &CsrTopo,
    pw: &PackedFwd,
    bias: &[f32],
    y: &mut [f32],
    scratch: &mut PanelScratch,
) {
    crate::obs_counter!("kernels.packed_spmm_bias_fwd").inc();
    let (ind, outd) = (topo.rows, topo.cols);
    debug_assert_eq!(x.len(), batch * ind);
    debug_assert_eq!(y.len(), batch * outd);
    debug_assert_eq!(bias.len(), outd);
    let ncb = topo.blocks.n_col_blocks();
    let pool = exec.pool_for(batch * topo.nnz().max(outd));
    let yp = MutPtr(y.as_mut_ptr());
    // Per-task staging region length: the worst row covers every case
    // (a `Some(j)` sub-range is a subset of its row).
    let rl = pw.max_row.max(1);
    if use_panels(batch) {
        let npanels = batch / LANES;
        let tail = npanels * LANES;
        let units = npanels + (tail < batch) as usize;
        let ncb_eff = ncb.max(1);
        let n_tasks = units * ncb_eff;
        let (xp, yacc, di, dv) =
            scratch.packed_bufs(npanels * ind, npanels * outd, n_tasks * rl);
        pack_panels(x, ind, npanels, xp);
        let xp: &[F32Lanes] = xp;
        match pool {
            Some(pool) if ncb > 1 || units > 1 => {
                let ap = MutPtr(yacc.as_mut_ptr());
                let dip = MutPtr(di.as_mut_ptr());
                let dvp = MutPtr(dv.as_mut_ptr());
                dispatch(pool, n_tasks, &|t| {
                    let (u, j) = (t / ncb_eff, t % ncb_eff);
                    let (c0, c1, blk) = if ncb > 1 {
                        (
                            topo.blocks.col_blk[j] as usize,
                            topo.blocks.col_blk[j + 1] as usize,
                            Some(j),
                        )
                    } else {
                        (0, outd, None)
                    };
                    // SAFETY: staging entries [t·rl, (t+1)·rl) — owned by
                    // task t alone (MutPtr contract).
                    let (di, dv) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(dip.0.add(t * rl), rl),
                            std::slice::from_raw_parts_mut(dvp.0.add(t * rl), rl),
                        )
                    };
                    if u < npanels {
                        // SAFETY: accumulator lanes [u·outd+c0, u·outd+c1)
                        // — owned by task (u, j) alone (MutPtr contract).
                        let acc = unsafe {
                            std::slice::from_raw_parts_mut(ap.0.add(u * outd + c0), c1 - c0)
                        };
                        packed_fwd_panel(
                            &xp[u * ind..(u + 1) * ind],
                            u * LANES,
                            topo,
                            pw,
                            bias,
                            c0,
                            c1,
                            blk,
                            acc,
                            yp,
                            outd,
                            di,
                            dv,
                        );
                    } else {
                        packed_fwd_flat_cols(x, tail, batch, topo, pw, bias, c0, c1, blk, yp, di, dv);
                    }
                });
            }
            _ => {
                for p in 0..npanels {
                    packed_fwd_panel(
                        &xp[p * ind..(p + 1) * ind],
                        p * LANES,
                        topo,
                        pw,
                        bias,
                        0,
                        outd,
                        None,
                        &mut yacc[p * outd..(p + 1) * outd],
                        yp,
                        outd,
                        &mut di[..rl],
                        &mut dv[..rl],
                    );
                }
                packed_fwd_flat_cols(
                    x,
                    tail,
                    batch,
                    topo,
                    pw,
                    bias,
                    0,
                    outd,
                    None,
                    yp,
                    &mut di[..rl],
                    &mut dv[..rl],
                );
            }
        }
    } else {
        match pool {
            Some(pool) if ncb > 1 => {
                let (di, dv) = scratch.decode_bufs(ncb * rl);
                let dip = MutPtr(di.as_mut_ptr());
                let dvp = MutPtr(dv.as_mut_ptr());
                dispatch(pool, ncb, &|j| {
                    let c0 = topo.blocks.col_blk[j] as usize;
                    let c1 = topo.blocks.col_blk[j + 1] as usize;
                    // SAFETY: staging entries [j·rl, (j+1)·rl) — owned by
                    // task j alone (MutPtr contract).
                    let (di, dv) = unsafe {
                        (
                            std::slice::from_raw_parts_mut(dip.0.add(j * rl), rl),
                            std::slice::from_raw_parts_mut(dvp.0.add(j * rl), rl),
                        )
                    };
                    packed_fwd_flat_cols(x, 0, batch, topo, pw, bias, c0, c1, Some(j), yp, di, dv);
                });
            }
            _ => {
                let (di, dv) = scratch.decode_bufs(rl);
                packed_fwd_flat_cols(x, 0, batch, topo, pw, bias, 0, outd, None, yp, di, dv);
            }
        }
    }
}

/// Packed twin of [`fwd_panel`]: decode the sub-range, then the
/// identical lane-8 accumulation.
#[allow(clippy::too_many_arguments)]
fn packed_fwd_panel(
    xp: &[F32Lanes],
    b0: usize,
    topo: &CsrTopo,
    pw: &PackedFwd,
    bias: &[f32],
    c0: usize,
    c1: usize,
    blk: Option<usize>,
    yacc: &mut [F32Lanes],
    y: MutPtr<f32>,
    outd: usize,
    di: &mut [u32],
    dv: &mut [f32],
) {
    for (c, acc) in (c0..c1).zip(yacc.iter_mut()) {
        *acc = F32Lanes::splat(bias[c]);
    }
    for (i, xl) in xp.iter().enumerate() {
        if !xl.any_nonzero() {
            continue; // every lane would skip row i: adds no terms
        }
        let (ks, n) = decode_row(pw, topo, i, blk, di);
        let vals = pw.vals.widen(ks, n, dv);
        for (k, &c) in di[..n].iter().enumerate() {
            let c = c as usize;
            yacc[c - c0] = yacc[c - c0].fma_nz(*xl, vals[k]);
        }
    }
    for l in 0..LANES {
        // SAFETY: columns [c0, c1) of batch row b0+l — this task's panel
        // and column range alone (MutPtr contract).
        let row = unsafe { std::slice::from_raw_parts_mut(y.0.add((b0 + l) * outd + c0), c1 - c0) };
        for (slot, acc) in row.iter_mut().zip(yacc.iter()) {
            *slot = acc.0[l];
        }
    }
}

/// Packed twin of [`fwd_flat_cols`] — the ragged-tail and small-batch
/// path (each batch row re-decodes, which only ever covers < LANES rows
/// on the panel path or batches too small to matter).
#[allow(clippy::too_many_arguments)]
fn packed_fwd_flat_cols(
    x: &[f32],
    b0: usize,
    b1: usize,
    topo: &CsrTopo,
    pw: &PackedFwd,
    bias: &[f32],
    c0: usize,
    c1: usize,
    blk: Option<usize>,
    y: MutPtr<f32>,
    di: &mut [u32],
    dv: &mut [f32],
) {
    let (ind, outd) = (topo.rows, topo.cols);
    for b in b0..b1 {
        let xrow = &x[b * ind..(b + 1) * ind];
        // SAFETY: columns [c0, c1) of batch row b — callers hand each
        // (row-range, column-range) region to exactly one task (MutPtr
        // contract).
        let yreg = unsafe { std::slice::from_raw_parts_mut(y.0.add(b * outd + c0), c1 - c0) };
        yreg.copy_from_slice(&bias[c0..c1]);
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let (ks, n) = decode_row(pw, topo, i, blk, di);
            let vals = pw.vals.widen(ks, n, dv);
            for (k, &c) in di[..n].iter().enumerate() {
                yreg[c as usize - c0] += xv * vals[k];
            }
        }
    }
}

// ---------------------------------------------------------------------
// Backward data product
// ---------------------------------------------------------------------

/// Backward data product: `dx = dy·Wᵀ` with `W` sparse. `dx` is fully
/// overwritten. Output partition: ROW blocks × batch panels — `dx[b,i]`
/// depends only on row `i`'s structure, so units own disjoint `dx`
/// regions. The panel path walks each row's index stream once for eight
/// batch elements (upstream gradients transposed into `scratch`).
pub fn spmm_back_dx(
    exec: Exec,
    dy: &[f32],
    batch: usize,
    topo: &CsrTopo,
    w: &[f32],
    dx: &mut [f32],
    scratch: &mut PanelScratch,
) {
    let (ind, outd) = (topo.rows, topo.cols);
    debug_assert_eq!(dy.len(), batch * outd);
    debug_assert_eq!(dx.len(), batch * ind);
    crate::obs_counter!("kernels.spmm_back_dx").inc();
    let nrb = topo.blocks.n_row_blocks();
    let pool = exec.pool_for(batch * topo.nnz().max(ind));
    let dxp = MutPtr(dx.as_mut_ptr());
    if use_panels(batch) {
        let npanels = batch / LANES;
        let tail = npanels * LANES;
        let dyp = scratch.x_buf(npanels * outd);
        pack_panels(dy, outd, npanels, dyp);
        let dyp: &[F32Lanes] = dyp;
        let units = npanels + (tail < batch) as usize;
        match pool {
            // As in the forward: batch panels are their own work-unit
            // axis, so single-row-block layers still scale.
            Some(pool) if nrb > 1 || units > 1 => {
                let nrb_eff = nrb.max(1);
                dispatch(pool, units * nrb_eff, &|t| {
                    let (u, rb) = (t / nrb_eff, t % nrb_eff);
                    let (r0, r1) = if nrb > 1 {
                        (
                            topo.blocks.row_blk[rb] as usize,
                            topo.blocks.row_blk[rb + 1] as usize,
                        )
                    } else {
                        (0, ind)
                    };
                    if u < npanels {
                        dx_panel(&dyp[u * outd..(u + 1) * outd], u * LANES, topo, w, r0, r1, dxp);
                    } else {
                        dx_flat(dy, tail, batch, topo, w, r0, r1, dxp);
                    }
                });
            }
            _ => {
                for p in 0..npanels {
                    dx_panel(&dyp[p * outd..(p + 1) * outd], p * LANES, topo, w, 0, ind, dxp);
                }
                dx_flat(dy, tail, batch, topo, w, 0, ind, dxp);
            }
        }
    } else {
        match pool {
            Some(pool) if nrb > 1 => {
                dispatch(pool, nrb, &|t| {
                    let r0 = topo.blocks.row_blk[t] as usize;
                    let r1 = topo.blocks.row_blk[t + 1] as usize;
                    dx_flat(dy, 0, batch, topo, w, r0, r1, dxp);
                });
            }
            _ => dx_flat(dy, 0, batch, topo, w, 0, ind, dxp),
        }
    }
}

/// One batch panel × one row range of `dx`: the row's accumulation runs
/// entirely in lane registers (no panel output buffer needed), in the
/// flat loop's entry order.
fn dx_panel(
    dyp: &[F32Lanes],
    b0: usize,
    topo: &CsrTopo,
    w: &[f32],
    r0: usize,
    r1: usize,
    dx: MutPtr<f32>,
) {
    let (ind, outd) = (topo.rows, topo.cols);
    for i in r0..r1 {
        let wrow = i * outd;
        let mut acc = F32Lanes::zero();
        for &c in topo.row(i) {
            acc = acc.fma(dyp[c as usize], w[wrow + c as usize]);
        }
        for l in 0..LANES {
            // SAFETY: element (b0+l, i) — this task's panel and row
            // range alone (MutPtr contract).
            unsafe { *dx.0.add((b0 + l) * ind + i) = acc.0[l] };
        }
    }
}

/// Flat scalar `dx` over batch rows `[b0, b1)` × structure rows
/// `[r0, r1)` — the ragged-tail path and the `reference` body.
#[allow(clippy::too_many_arguments)]
fn dx_flat(
    dy: &[f32],
    b0: usize,
    b1: usize,
    topo: &CsrTopo,
    w: &[f32],
    r0: usize,
    r1: usize,
    dx: MutPtr<f32>,
) {
    let (ind, outd) = (topo.rows, topo.cols);
    for b in b0..b1 {
        let dyrow = &dy[b * outd..(b + 1) * outd];
        for i in r0..r1 {
            let wrow = i * outd;
            let mut acc = 0.0f32;
            for &c in topo.row(i) {
                acc += w[wrow + c as usize] * dyrow[c as usize];
            }
            // SAFETY: element (b, i) — this task's batch and row range
            // alone (MutPtr contract).
            unsafe { *dx.0.add(b * ind + i) = acc };
        }
    }
}

// ---------------------------------------------------------------------
// Backward weight products
// ---------------------------------------------------------------------

/// Backward weight product at the active positions only:
/// `dw_vals[k] += Σ_b x[b,i]·dy[b,o]` for the k-th structural entry
/// `(i,o)`. `dw_vals` is parallel to `topo.col_idx`; the caller zeroes
/// it. Output partition: ROW blocks — entry `k` lives in exactly one
/// row block's contiguous `k` range. The panel path vectorizes over
/// *entries* (lane = one `k`), never across the batch: each entry's
/// accumulation must stay in increasing-batch order, so batch panels
/// are walked sequentially inside every work unit.
pub fn spmm_back_dw(
    exec: Exec,
    x: &[f32],
    dy: &[f32],
    batch: usize,
    topo: &CsrTopo,
    dw_vals: &mut [f32],
    scratch: &mut PanelScratch,
) {
    let ind = topo.rows;
    debug_assert_eq!(dw_vals.len(), topo.nnz());
    crate::obs_counter!("kernels.spmm_back_dw").inc();
    let nrb = topo.blocks.n_row_blocks();
    let pool = exec.pool_for(batch * topo.nnz());
    let dwp = MutPtr(dw_vals.as_mut_ptr());
    let npanels = if use_panels(batch) { batch / LANES } else { 0 };
    let xp: &[F32Lanes] = if npanels > 0 {
        let xp = scratch.x_buf(npanels * ind);
        pack_panels(x, ind, npanels, xp);
        xp
    } else {
        &[]
    };
    match pool {
        Some(pool) if nrb > 1 => {
            dispatch(pool, nrb, &|t| {
                let r0 = topo.blocks.row_blk[t] as usize;
                let r1 = topo.blocks.row_blk[t + 1] as usize;
                dw_rows(x, dy, batch, npanels, xp, topo, r0, r1, dwp);
            });
        }
        _ => dw_rows(x, dy, batch, npanels, xp, topo, 0, topo.rows, dwp),
    }
}

/// Weight-gradient accumulation for structure rows `[r0, r1)`: batch
/// panels first (entries chunked into lane vectors; per entry the term
/// order is increasing batch row), then the ragged batch tail flat.
#[allow(clippy::too_many_arguments)]
fn dw_rows(
    x: &[f32],
    dy: &[f32],
    batch: usize,
    npanels: usize,
    xp_all: &[F32Lanes],
    topo: &CsrTopo,
    r0: usize,
    r1: usize,
    dw: MutPtr<f32>,
) {
    let (ind, outd) = (topo.rows, topo.cols);
    for p in 0..npanels {
        let xp = &xp_all[p * ind..(p + 1) * ind];
        let dyrows = &dy[p * LANES * outd..];
        for i in r0..r1 {
            let xl = xp[i];
            if !xl.any_nonzero() {
                continue; // every lane skips row i: adds no terms
            }
            let (ks, ke) = (topo.row_ptr[i] as usize, topo.row_ptr[i + 1] as usize);
            let mut k = ks;
            while k + LANES <= ke {
                let cols = &topo.col_idx[k..k + LANES];
                // SAFETY: entries [k, k+LANES) fall inside this task's
                // row block (MutPtr contract).
                let dwreg = unsafe { std::slice::from_raw_parts_mut(dw.0.add(k), LANES) };
                let mut acc = F32Lanes::from_slice(dwreg);
                for l in 0..LANES {
                    let xv = xl.0[l];
                    if xv != 0.0 {
                        let dyl = F32Lanes::gather(&dyrows[l * outd..(l + 1) * outd], cols);
                        acc = acc.fma(dyl, xv);
                    }
                }
                acc.write(dwreg);
                k += LANES;
            }
            for k in k..ke {
                let c = topo.col_idx[k] as usize;
                // SAFETY: as above.
                let slot = unsafe { &mut *dw.0.add(k) };
                for l in 0..LANES {
                    let xv = xl.0[l];
                    if xv != 0.0 {
                        *slot += xv * dyrows[l * outd + c];
                    }
                }
            }
        }
    }
    dw_flat(x, dy, npanels * LANES, batch, topo, r0, r1, dw);
}

/// Flat scalar `dw` over batch rows `[b0, b1)` × structure rows
/// `[r0, r1)` — the ragged-tail path and the `reference` body.
#[allow(clippy::too_many_arguments)]
fn dw_flat(
    x: &[f32],
    dy: &[f32],
    b0: usize,
    b1: usize,
    topo: &CsrTopo,
    r0: usize,
    r1: usize,
    dw: MutPtr<f32>,
) {
    let (ind, outd) = (topo.rows, topo.cols);
    for b in b0..b1 {
        let xrow = &x[b * ind..(b + 1) * ind];
        let dyrow = &dy[b * outd..(b + 1) * outd];
        for i in r0..r1 {
            let xv = xrow[i];
            if xv == 0.0 {
                continue;
            }
            let (ks, ke) = (topo.row_ptr[i] as usize, topo.row_ptr[i + 1] as usize);
            for k in ks..ke {
                // SAFETY: entry k is in this task's row block (MutPtr
                // contract).
                unsafe { *dw.0.add(k) += xv * dyrow[topo.col_idx[k] as usize] };
            }
        }
    }
}

/// Full dense weight gradient `dw[i,o] += Σ_b x[b,i]·dy[b,o]` — the RigL
/// grow signal (∇ w.r.t. *every* connection, active or not). The caller
/// zeroes `dw`. O(in·out·batch): paid only on mask-update steps, and the
/// heaviest single kernel in a RigL step. Output partition: uniform
/// input-row chunks; the panel path vectorizes over output columns with
/// batch panels walked sequentially (per-element term order stays
/// increasing batch row, skip applied per lane).
#[allow(clippy::too_many_arguments)]
pub fn dense_back_dw(
    exec: Exec,
    x: &[f32],
    dy: &[f32],
    batch: usize,
    in_dim: usize,
    out_dim: usize,
    dw: &mut [f32],
    scratch: &mut PanelScratch,
) {
    debug_assert_eq!(dw.len(), in_dim * out_dim);
    crate::obs_counter!("kernels.dense_back_dw").inc();
    let pool = exec.pool_for(batch * in_dim * out_dim);
    let dwp = MutPtr(dw.as_mut_ptr());
    let npanels = if use_panels(batch) { batch / LANES } else { 0 };
    let xp: &[F32Lanes] = if npanels > 0 {
        let xp = scratch.x_buf(npanels * in_dim);
        pack_panels(x, in_dim, npanels, xp);
        xp
    } else {
        &[]
    };
    match pool {
        Some(pool) => {
            let n_tasks = (pool.threads() * 2).clamp(1, in_dim);
            let chunk = in_dim.div_ceil(n_tasks);
            dispatch(pool, n_tasks, &|t| {
                let i0 = t * chunk;
                let i1 = ((t + 1) * chunk).min(in_dim);
                if i0 >= i1 {
                    return;
                }
                dense_rows(x, dy, batch, npanels, xp, in_dim, out_dim, i0, i1, dwp);
            });
        }
        _ => dense_rows(x, dy, batch, npanels, xp, in_dim, out_dim, 0, in_dim, dwp),
    }
}

/// Dense weight gradient for input rows `[i0, i1)`: batch panels first
/// (output columns chunked into lane vectors, the `dw` row loaded once
/// per eight batch elements), then the ragged batch tail flat.
#[allow(clippy::too_many_arguments)]
fn dense_rows(
    x: &[f32],
    dy: &[f32],
    batch: usize,
    npanels: usize,
    xp_all: &[F32Lanes],
    in_dim: usize,
    out_dim: usize,
    i0: usize,
    i1: usize,
    dw: MutPtr<f32>,
) {
    for p in 0..npanels {
        let xp = &xp_all[p * in_dim..(p + 1) * in_dim];
        let dyrows = &dy[p * LANES * out_dim..];
        for i in i0..i1 {
            let xl = xp[i];
            if !xl.any_nonzero() {
                continue;
            }
            // SAFETY: dense row i — this task's input-row range alone
            // (MutPtr contract).
            let drow = unsafe { std::slice::from_raw_parts_mut(dw.0.add(i * out_dim), out_dim) };
            let mut o = 0;
            while o + LANES <= out_dim {
                let mut acc = F32Lanes::from_slice(&drow[o..]);
                for l in 0..LANES {
                    let xv = xl.0[l];
                    if xv != 0.0 {
                        acc = acc.fma(F32Lanes::from_slice(&dyrows[l * out_dim + o..]), xv);
                    }
                }
                acc.write(&mut drow[o..]);
                o += LANES;
            }
            for o in o..out_dim {
                let slot = &mut drow[o];
                for l in 0..LANES {
                    let xv = xl.0[l];
                    if xv != 0.0 {
                        *slot += xv * dyrows[l * out_dim + o];
                    }
                }
            }
        }
    }
    dense_flat(x, dy, npanels * LANES, batch, in_dim, out_dim, i0, i1, dw);
}

/// Flat scalar dense gradient over batch rows `[b0, b1)` × input rows
/// `[i0, i1)` — the ragged-tail path and the `reference` body.
#[allow(clippy::too_many_arguments)]
fn dense_flat(
    x: &[f32],
    dy: &[f32],
    b0: usize,
    b1: usize,
    in_dim: usize,
    out_dim: usize,
    i0: usize,
    i1: usize,
    dw: MutPtr<f32>,
) {
    for b in b0..b1 {
        let xrow = &x[b * in_dim..(b + 1) * in_dim];
        let dyrow = &dy[b * out_dim..(b + 1) * out_dim];
        for i in i0..i1 {
            let xv = xrow[i];
            if xv == 0.0 {
                continue;
            }
            // SAFETY: dense row i — this task's input-row range alone
            // (MutPtr contract).
            let drow = unsafe { std::slice::from_raw_parts_mut(dw.0.add(i * out_dim), out_dim) };
            for (slot, &d) in drow.iter_mut().zip(dyrow) {
                *slot += xv * d;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Elementwise / small kernels
// ---------------------------------------------------------------------

/// Bias gradient `db[o] = Σ_b dy[b,o]` (overwritten). Always serial:
/// O(batch·out) streaming adds are memory-bound and smaller than one
/// fork-join round for every model in the zoo.
pub fn bias_grad(dy: &[f32], batch: usize, out_dim: usize, db: &mut [f32]) {
    debug_assert_eq!(db.len(), out_dim);
    db.fill(0.0);
    for b in 0..batch {
        let dyrow = &dy[b * out_dim..(b + 1) * out_dim];
        for (slot, &d) in db.iter_mut().zip(dyrow) {
            *slot += d;
        }
    }
}

/// In-place ReLU. Serial: memory-bound.
pub fn relu(h: &mut [f32]) {
    for v in h {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// ReLU backward: zero `dh` wherever the post-activation `act` is ≤ 0
/// (matches `jax.nn.relu`'s zero subgradient at 0). Serial: memory-bound.
pub fn relu_bwd(dh: &mut [f32], act: &[f32]) {
    for (d, &a) in dh.iter_mut().zip(act) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

// ---------------------------------------------------------------------
// Softmax cross-entropy
// ---------------------------------------------------------------------

/// One row of label-smoothed softmax cross-entropy: writes the logit
/// gradient into `drow` and returns the row's loss contribution. Both
/// the serial and parallel entry points — and the panel path, per lane
/// — run exactly this sequence of operations per row, which is what
/// keeps them bit-identical.
#[inline]
fn xent_row(
    row: &[f32],
    drow: &mut [f32],
    target: usize,
    smoothing: f32,
    uniform: f32,
    inv_b: f32,
) -> f64 {
    debug_assert!(target < row.len());
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for &l in row {
        z += (l - m).exp();
    }
    let lse = m + z.ln();
    let nll = (lse - row[target]) as f64;
    let loss = if smoothing > 0.0 {
        let mean_nll: f64 = row.iter().map(|&l| (lse - l) as f64).sum::<f64>() / row.len() as f64;
        (1.0 - smoothing as f64) * nll + smoothing as f64 * mean_nll
    } else {
        nll
    };
    for (j, (slot, &l)) in drow.iter_mut().zip(row).enumerate() {
        let p = (l - lse).exp();
        let hard = if j == target { 1.0 - smoothing } else { 0.0 };
        *slot = (p - hard - uniform) * inv_b;
    }
    loss
}

/// Label-smoothed softmax cross-entropy, mean over the batch (nats), and
/// its gradient w.r.t. the logits (already scaled by 1/batch) written to
/// `dlogits`. Mirrors `smoothed_xent` + `jax.value_and_grad` on the
/// python side: `d/dl_j = p_j − ((1−s)·1{j=y} + s/K)`. Serial reference;
/// the training session uses [`softmax_xent_grad_par`].
pub fn softmax_xent_grad(
    logits: &[f32],
    batch: usize,
    classes: usize,
    y: &[i32],
    smoothing: f32,
    dlogits: &mut [f32],
) -> f64 {
    debug_assert_eq!(logits.len(), batch * classes);
    debug_assert_eq!(dlogits.len(), batch * classes);
    debug_assert_eq!(y.len(), batch);
    let inv_b = 1.0f32 / batch as f32;
    let uniform = smoothing / classes as f32;
    let mut loss_sum = 0.0f64;
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let drow = &mut dlogits[b * classes..(b + 1) * classes];
        loss_sum += xent_row(row, drow, y[b] as usize, smoothing, uniform, inv_b);
    }
    loss_sum / batch as f64
}

/// [`softmax_xent_grad`] with batch rows fanned over the pool in panel
/// units. `row_loss` (caller-owned, length `batch`) holds per-row
/// losses so the final reduction is a serial sum in batch order — the
/// same f64 sequence as the flat loop, hence bit-identical. The panel
/// path transposes each eight-row group so the max/sum folds run
/// lane-parallel while every lane's fold order (and its `exp`/`ln`
/// calls) matches [`xent_row`] exactly.
#[allow(clippy::too_many_arguments)]
pub fn softmax_xent_grad_par(
    exec: Exec,
    logits: &[f32],
    batch: usize,
    classes: usize,
    y: &[i32],
    smoothing: f32,
    dlogits: &mut [f32],
    row_loss: &mut [f64],
    scratch: &mut PanelScratch,
) -> f64 {
    debug_assert_eq!(row_loss.len(), batch);
    debug_assert_eq!(logits.len(), batch * classes);
    debug_assert_eq!(dlogits.len(), batch * classes);
    debug_assert_eq!(y.len(), batch);
    // exp/ln make softmax rows ~an order heavier than a MAC; weigh that
    // into the autotune gate.
    let pool = exec.pool_for(batch * classes * 8);
    if !use_panels(batch) || classes == 0 {
        return match pool {
            Some(pool) if batch > 1 => {
                let inv_b = 1.0f32 / batch as f32;
                let uniform = smoothing / classes as f32;
                let n_tasks = pool.threads().clamp(1, batch);
                let chunk = batch.div_ceil(n_tasks);
                let dlp = MutPtr(dlogits.as_mut_ptr());
                let rlp = MutPtr(row_loss.as_mut_ptr());
                dispatch(pool, n_tasks, &|t| {
                    let b0 = t * chunk;
                    let b1 = ((t + 1) * chunk).min(batch);
                    if b0 >= b1 {
                        return;
                    }
                    // SAFETY: batch rows [b0, b1) of dlogits and
                    // row_loss — owned by task t alone (MutPtr contract).
                    let dreg = unsafe {
                        std::slice::from_raw_parts_mut(dlp.0.add(b0 * classes), (b1 - b0) * classes)
                    };
                    let lreg = unsafe { std::slice::from_raw_parts_mut(rlp.0.add(b0), b1 - b0) };
                    for b in b0..b1 {
                        let row = &logits[b * classes..(b + 1) * classes];
                        let drow = &mut dreg[(b - b0) * classes..(b - b0 + 1) * classes];
                        lreg[b - b0] =
                            xent_row(row, drow, y[b] as usize, smoothing, uniform, inv_b);
                    }
                });
                let mut loss_sum = 0.0f64;
                for &l in row_loss.iter() {
                    loss_sum += l;
                }
                loss_sum / batch as f64
            }
            _ => softmax_xent_grad(logits, batch, classes, y, smoothing, dlogits),
        };
    }
    let inv_b = 1.0f32 / batch as f32;
    let uniform = smoothing / classes as f32;
    let npanels = batch / LANES;
    let tail = npanels * LANES;
    let lt = scratch.x_buf(npanels * classes);
    pack_panels(logits, classes, npanels, lt);
    let lt: &[F32Lanes] = lt;
    let dlp = MutPtr(dlogits.as_mut_ptr());
    let rlp = MutPtr(row_loss.as_mut_ptr());
    let units = npanels + (tail < batch) as usize;
    let run_unit = |u: usize| {
        if u < npanels {
            softmax_panel(
                &lt[u * classes..(u + 1) * classes],
                u * LANES,
                classes,
                y,
                smoothing,
                uniform,
                inv_b,
                dlp,
                rlp,
            );
        } else {
            for b in tail..batch {
                let row = &logits[b * classes..(b + 1) * classes];
                // SAFETY: batch row b of dlogits and row_loss — the
                // tail unit's alone (MutPtr contract).
                let drow =
                    unsafe { std::slice::from_raw_parts_mut(dlp.0.add(b * classes), classes) };
                let loss = xent_row(row, drow, y[b] as usize, smoothing, uniform, inv_b);
                unsafe { *rlp.0.add(b) = loss };
            }
        }
    };
    match pool {
        Some(pool) if units > 1 => dispatch(pool, units, &run_unit),
        _ => {
            for u in 0..units {
                run_unit(u);
            }
        }
    }
    let mut loss_sum = 0.0f64;
    for &l in row_loss.iter() {
        loss_sum += l;
    }
    loss_sum / batch as f64
}

/// One eight-row panel of softmax cross-entropy. `lt` holds the panel's
/// logits transposed (class-major lane vectors); per lane the fold
/// orders and formulas are exactly [`xent_row`]'s, with the `exp`/`ln`
/// calls left scalar so their bits match the libm calls the scalar path
/// makes.
#[allow(clippy::too_many_arguments)]
fn softmax_panel(
    lt: &[F32Lanes],
    b0: usize,
    classes: usize,
    y: &[i32],
    smoothing: f32,
    uniform: f32,
    inv_b: f32,
    dl: MutPtr<f32>,
    rl: MutPtr<f64>,
) {
    let mut m = F32Lanes::splat(f32::NEG_INFINITY);
    for lj in lt {
        m = m.max(*lj);
    }
    let mut z = F32Lanes::zero();
    for lj in lt {
        for l in 0..LANES {
            z.0[l] += (lj.0[l] - m.0[l]).exp();
        }
    }
    let mut lse = [0.0f32; LANES];
    for l in 0..LANES {
        lse[l] = m.0[l] + z.0[l].ln();
    }
    for l in 0..LANES {
        let target = y[b0 + l] as usize;
        debug_assert!(target < classes);
        let nll = (lse[l] - lt[target].0[l]) as f64;
        let loss = if smoothing > 0.0 {
            let mut sum = 0.0f64;
            for lj in lt {
                sum += (lse[l] - lj.0[l]) as f64;
            }
            let mean_nll = sum / classes as f64;
            (1.0 - smoothing as f64) * nll + smoothing as f64 * mean_nll
        } else {
            nll
        };
        // SAFETY: row_loss[b0+l] — this panel's batch rows alone
        // (MutPtr contract).
        unsafe { *rl.0.add(b0 + l) = loss };
    }
    for (j, lj) in lt.iter().enumerate() {
        for l in 0..LANES {
            let p = (lj.0[l] - lse[l]).exp();
            let hard = if j == y[b0 + l] as usize {
                1.0 - smoothing
            } else {
                0.0
            };
            // SAFETY: dlogits row b0+l — this panel's alone (MutPtr
            // contract).
            unsafe { *dl.0.add((b0 + l) * classes + j) = (p - hard - uniform) * inv_b };
        }
    }
}

/// Eval metrics for classification: `(Σ plain cross-entropy, Σ correct)`,
/// mirroring `classify_metrics` (argmax ties break to the first index,
/// like `jnp.argmax`). Serial: eval is off the hot path.
pub fn xent_metrics(logits: &[f32], batch: usize, classes: usize, y: &[i32]) -> (f64, f64) {
    let (mut nll_sum, mut correct) = (0.0f64, 0.0f64);
    for b in 0..batch {
        let row = &logits[b * classes..(b + 1) * classes];
        let target = y[b] as usize;
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &l in row {
            z += (l - m).exp();
        }
        let lse = m + z.ln();
        nll_sum += (lse - row[target]) as f64;
        let mut arg = 0usize;
        for (j, &l) in row.iter().enumerate() {
            if l > row[arg] {
                arg = j;
            }
        }
        if arg == target {
            correct += 1.0;
        }
    }
    (nll_sum, correct)
}

// ---------------------------------------------------------------------
// Optimizer
// ---------------------------------------------------------------------

/// SGD-with-momentum over the active entries of one sparse weight tensor,
/// mirroring the sgdm train artifact exactly:
/// `g = dw + wd·q; v ← µ·v + g; q ← q − lr·v` (off-mask entries are zero
/// in `w`, `v` AND `dw`, so skipping them reproduces the artifact's
/// `(·)·m` re-masking for free). Output partition: ROW blocks — a
/// block's flat positions `i·cols + c` with `i ∈ [r0, r1)` never leave
/// its region. The panel path chunks entries eight at a time
/// (gather/compute/scatter); per entry the op sequence is the scalar
/// formula's, so chunking is invisible bitwise.
#[allow(clippy::too_many_arguments)]
pub fn sgdm_update_sparse(
    exec: Exec,
    topo: &CsrTopo,
    w: &mut [f32],
    v: &mut [f32],
    dw_vals: &[f32],
    lr: f32,
    momentum: f32,
    weight_decay: f32,
) {
    debug_assert_eq!(dw_vals.len(), topo.nnz());
    let nrb = topo.blocks.n_row_blocks();
    let lanes = panel_kernels();
    let wp = MutPtr(w.as_mut_ptr());
    let vp = MutPtr(v.as_mut_ptr());
    match exec.pool_for(topo.nnz() * 4) {
        Some(pool) if nrb > 1 => {
            dispatch(pool, nrb, &|t| {
                let r0 = topo.blocks.row_blk[t] as usize;
                let r1 = topo.blocks.row_blk[t + 1] as usize;
                sgdm_rows(topo, r0, r1, wp, vp, dw_vals, lr, momentum, weight_decay, lanes);
            });
        }
        _ => sgdm_rows(
            topo,
            0,
            topo.rows,
            wp,
            vp,
            dw_vals,
            lr,
            momentum,
            weight_decay,
            lanes,
        ),
    }
}

/// The SGDM update for structure rows `[r0, r1)`, entry-chunked into
/// lane vectors when `lanes` is set (ragged chunk tails and the
/// `reference` path run the scalar formula, which is bitwise the same
/// per entry).
#[allow(clippy::too_many_arguments)]
fn sgdm_rows(
    topo: &CsrTopo,
    r0: usize,
    r1: usize,
    w: MutPtr<f32>,
    v: MutPtr<f32>,
    dw_vals: &[f32],
    lr: f32,
    mu: f32,
    wd: f32,
    lanes: bool,
) {
    let cols = topo.cols;
    for i in r0..r1 {
        // SAFETY: flat positions [i·cols, (i+1)·cols) of w and v — rows
        // [r0, r1) are this task's alone (MutPtr contract).
        let wrow = unsafe { std::slice::from_raw_parts_mut(w.0.add(i * cols), cols) };
        let vrow = unsafe { std::slice::from_raw_parts_mut(v.0.add(i * cols), cols) };
        let (ks, ke) = (topo.row_ptr[i] as usize, topo.row_ptr[i + 1] as usize);
        let mut k = ks;
        if lanes {
            while k + LANES <= ke {
                let idx = &topo.col_idx[k..k + LANES];
                let wl = F32Lanes::gather(wrow, idx);
                let vl = F32Lanes::gather(vrow, idx);
                let g = F32Lanes::from_slice(&dw_vals[k..]).fma(wl, wd);
                let v2 = g.fma(vl, mu);
                v2.scatter(vrow, idx);
                wl.fma(v2, -lr).scatter(wrow, idx);
                k += LANES;
            }
        }
        for k in k..ke {
            let f = topo.col_idx[k] as usize;
            let g = dw_vals[k] + wd * wrow[f];
            let v2 = mu * vrow[f] + g;
            vrow[f] = v2;
            wrow[f] -= lr * v2;
        }
    }
}

/// SGD-with-momentum over a dense 1-D tensor (biases), lane-chunked
/// (identical per-element arithmetic; ragged tail scalar).
pub fn sgdm_update_dense(
    w: &mut [f32],
    v: &mut [f32],
    dw: &[f32],
    lr: f32,
    momentum: f32,
    weight_decay: f32,
) {
    let n = w.len();
    debug_assert_eq!(v.len(), n);
    debug_assert_eq!(dw.len(), n);
    let mut i = 0;
    if panel_kernels() {
        while i + LANES <= n {
            let wl = F32Lanes::from_slice(&w[i..]);
            let vl = F32Lanes::from_slice(&v[i..]);
            let g = F32Lanes::from_slice(&dw[i..]).fma(wl, weight_decay);
            let v2 = g.fma(vl, momentum);
            v2.write(&mut v[i..]);
            wl.fma(v2, -lr).write(&mut w[i..]);
            i += LANES;
        }
    }
    for ((q, vv), &g0) in w[i..].iter_mut().zip(v[i..].iter_mut()).zip(&dw[i..]) {
        let g = g0 + weight_decay * *q;
        let v2 = momentum * *vv + g;
        *vv = v2;
        *q -= lr * v2;
    }
}

// ---------------------------------------------------------------------
// Scalar reference implementations
// ---------------------------------------------------------------------

/// Flat scalar reference implementations — the bitwise oracle for the
/// panel paths and the body the ragged tails run. Each function is the
/// pre-SIMD serial loop; `tests/simd_determinism.rs` asserts every
/// panel kernel equals these in bits across the full batch × sparsity ×
/// threads grid, and the re-exported [`softmax_xent_grad`] (already the
/// serial flat loop) plays the same role for the softmax.
pub mod reference {
    use super::*;

    /// Scalar [`super::spmm_bias_fwd`].
    pub fn spmm_bias_fwd(
        x: &[f32],
        batch: usize,
        topo: &CsrTopo,
        w: &[f32],
        bias: &[f32],
        y: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), batch * topo.rows);
        debug_assert_eq!(y.len(), batch * topo.cols);
        let yp = MutPtr(y.as_mut_ptr());
        fwd_flat_cols(x, 0, batch, topo, &DenseW(w), bias, 0, topo.cols, None, yp);
    }

    /// Scalar [`super::csr_spmm_bias_fwd`].
    pub fn csr_spmm_bias_fwd(
        x: &[f32],
        batch: usize,
        topo: &CsrTopo,
        vals: &[f32],
        bias: &[f32],
        y: &mut [f32],
    ) {
        debug_assert_eq!(vals.len(), topo.nnz());
        debug_assert_eq!(y.len(), batch * topo.cols);
        let yp = MutPtr(y.as_mut_ptr());
        fwd_flat_cols(x, 0, batch, topo, &CsrVals(vals), bias, 0, topo.cols, None, yp);
    }

    /// Scalar [`super::spmm_back_dx`].
    pub fn spmm_back_dx(dy: &[f32], batch: usize, topo: &CsrTopo, w: &[f32], dx: &mut [f32]) {
        debug_assert_eq!(dy.len(), batch * topo.cols);
        debug_assert_eq!(dx.len(), batch * topo.rows);
        dx_flat(dy, 0, batch, topo, w, 0, topo.rows, MutPtr(dx.as_mut_ptr()));
    }

    /// Scalar [`super::spmm_back_dw`].
    pub fn spmm_back_dw(x: &[f32], dy: &[f32], batch: usize, topo: &CsrTopo, dw_vals: &mut [f32]) {
        debug_assert_eq!(dw_vals.len(), topo.nnz());
        dw_flat(x, dy, 0, batch, topo, 0, topo.rows, MutPtr(dw_vals.as_mut_ptr()));
    }

    /// Scalar [`super::dense_back_dw`].
    pub fn dense_back_dw(
        x: &[f32],
        dy: &[f32],
        batch: usize,
        in_dim: usize,
        out_dim: usize,
        dw: &mut [f32],
    ) {
        debug_assert_eq!(dw.len(), in_dim * out_dim);
        dense_flat(x, dy, 0, batch, in_dim, out_dim, 0, in_dim, MutPtr(dw.as_mut_ptr()));
    }

    /// Scalar [`super::sgdm_update_sparse`].
    #[allow(clippy::too_many_arguments)]
    pub fn sgdm_update_sparse(
        topo: &CsrTopo,
        w: &mut [f32],
        v: &mut [f32],
        dw_vals: &[f32],
        lr: f32,
        momentum: f32,
        weight_decay: f32,
    ) {
        debug_assert_eq!(dw_vals.len(), topo.nnz());
        sgdm_rows(
            topo,
            0,
            topo.rows,
            MutPtr(w.as_mut_ptr()),
            MutPtr(v.as_mut_ptr()),
            dw_vals,
            lr,
            momentum,
            weight_decay,
            false,
        );
    }

    /// Scalar [`super::sgdm_update_dense`].
    pub fn sgdm_update_dense(
        w: &mut [f32],
        v: &mut [f32],
        dw: &[f32],
        lr: f32,
        momentum: f32,
        weight_decay: f32,
    ) {
        for ((q, vv), &g0) in w.iter_mut().zip(v.iter_mut()).zip(dw) {
            let g = g0 + weight_decay * *q;
            let v2 = momentum * *vv + g;
            *vv = v2;
            *q -= lr * v2;
        }
    }

    pub use super::softmax_xent_grad;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn dense_mm(x: &[f32], w: &[f32], b: usize, ind: usize, outd: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; b * outd];
        for bi in 0..b {
            for i in 0..ind {
                for o in 0..outd {
                    y[bi * outd + o] += x[bi * ind + i] * w[i * outd + o];
                }
            }
        }
        y
    }

    /// Random masked layer: returns (masked weights, topo).
    fn setup(rng: &mut Rng, ind: usize, outd: usize, density: f64) -> (Vec<f32>, CsrTopo) {
        let mut w = vec![0.0f32; ind * outd];
        let mut mask = vec![0.0f32; ind * outd];
        for (wi, mi) in w.iter_mut().zip(mask.iter_mut()) {
            if rng.next_f64() < density {
                *mi = 1.0;
                *wi = rng.next_f32() - 0.5;
            }
        }
        let topo = CsrTopo::from_mask(&mask, ind, outd);
        (w, topo)
    }

    #[test]
    fn spmm_matches_dense_oracle() {
        let mut rng = Rng::new(1);
        let mut s = PanelScratch::default();
        for &(b, ind, outd, density) in
            &[(1, 4, 3, 1.0), (3, 8, 5, 0.4), (2, 6, 6, 0.0), (4, 5, 7, 0.7), (9, 6, 5, 0.5)]
        {
            let (w, topo) = setup(&mut rng, ind, outd, density);
            let x: Vec<f32> = (0..b * ind).map(|_| rng.next_f32() - 0.3).collect();
            let bias: Vec<f32> = (0..outd).map(|_| rng.next_f32()).collect();
            let mut y = vec![0.0f32; b * outd];
            spmm_bias_fwd(Exec::Serial, &x, b, &topo, &w, &bias, &mut y, &mut s);
            let mut want = dense_mm(&x, &w, b, ind, outd);
            for bi in 0..b {
                for o in 0..outd {
                    want[bi * outd + o] += bias[o];
                }
            }
            for (a, e) in y.iter().zip(&want) {
                assert!((a - e).abs() < 1e-5, "{a} vs {e}");
            }
        }
    }

    /// The value-carrying CSR forward must be bit-identical to the
    /// structure-only forward over the dense tensor it was gathered
    /// from, and batched rows must equal batch=1 rows exactly.
    #[test]
    fn csr_valued_fwd_matches_dense_backed_fwd_bitwise() {
        let mut rng = Rng::new(6);
        let mut s = PanelScratch::default();
        for &(b, ind, outd, density) in
            &[(1, 4, 3, 1.0), (3, 8, 5, 0.4), (4, 6, 6, 0.0), (9, 7, 5, 0.6)]
        {
            let (w, topo) = setup(&mut rng, ind, outd, density);
            // Positional gather: vals[k] = w[row(k)·outd + col(k)].
            let mut vals = Vec::with_capacity(topo.nnz());
            for i in 0..ind {
                for &c in topo.row(i) {
                    vals.push(w[i * outd + c as usize]);
                }
            }
            let x: Vec<f32> = (0..b * ind).map(|_| rng.next_f32() - 0.3).collect();
            let bias: Vec<f32> = (0..outd).map(|_| rng.next_f32()).collect();
            let mut y_dense = vec![0.0f32; b * outd];
            spmm_bias_fwd(Exec::Serial, &x, b, &topo, &w, &bias, &mut y_dense, &mut s);
            let mut y_csr = vec![0.0f32; b * outd];
            csr_spmm_bias_fwd(Exec::Serial, &x, b, &topo, &vals, &bias, &mut y_csr, &mut s);
            for (a, e) in y_csr.iter().zip(&y_dense) {
                assert_eq!(a.to_bits(), e.to_bits());
            }
            // Row independence: batch=1 execution per row, bit-identical.
            for bi in 0..b {
                let mut y1 = vec![0.0f32; outd];
                csr_spmm_bias_fwd(
                    Exec::Serial,
                    &x[bi * ind..(bi + 1) * ind],
                    1,
                    &topo,
                    &vals,
                    &bias,
                    &mut y1,
                    &mut s,
                );
                for (a, e) in y1.iter().zip(&y_csr[bi * outd..(bi + 1) * outd]) {
                    assert_eq!(a.to_bits(), e.to_bits());
                }
            }
        }
    }

    /// Test-local twin of the serve artifact's encoder: delta-pack a
    /// topology's indices against its own block decomposition.
    fn pack(topo: &CsrTopo) -> (Vec<u8>, Vec<u32>, usize) {
        let ncb = topo.blocks.n_col_blocks().max(1);
        let (mut idx, mut cb_byte, mut max_row) = (Vec::new(), Vec::new(), 0usize);
        for r in 0..topo.rows {
            max_row = max_row.max(topo.row_ptr[r + 1] as usize - topo.row_ptr[r] as usize);
            for j in 0..ncb {
                let (ks, ke) = topo.cb_range(r, j);
                crate::util::uvarint_encode((ke - ks) as u32, &mut idx);
                cb_byte.push(idx.len() as u32);
                let mut prev = topo.blocks.col_blk[j];
                for k in ks..ke {
                    crate::util::uvarint_encode(topo.col_idx[k] - prev, &mut idx);
                    prev = topo.col_idx[k];
                }
            }
        }
        (idx, cb_byte, max_row)
    }

    /// The decode-on-the-fly forward must be bit-identical to the plain
    /// value-carrying forward at every batch size (flat, panel and
    /// ragged-tail paths), block decomposition, and execution mode —
    /// the determinism contract extended across the format axis. The
    /// f16 variant must equal the plain forward over pre-widened values
    /// bitwise (widening is exact; only the encode rounding differs).
    #[test]
    fn packed_fwd_bit_identical_to_plain_across_exec_blocks_batch() {
        let mut rng = Rng::new(31);
        let mut s = PanelScratch::default();
        for &(ind, outd, density) in &[(12, 10, 0.5), (9, 17, 0.8), (6, 5, 0.0)] {
            let (w, mut topo) = setup(&mut rng, ind, outd, density);
            for &(target, maxb) in &[(4096usize, 16usize), (4, 4), (1, 8)] {
                topo.build_blocks_with(target, maxb);
                let mut vals = Vec::with_capacity(topo.nnz());
                for i in 0..ind {
                    for &c in topo.row(i) {
                        vals.push(w[i * outd + c as usize]);
                    }
                }
                let (idx, cb_byte, max_row) = pack(&topo);
                let halves: Vec<u16> =
                    vals.iter().map(|&v| crate::util::f32_to_f16_bits(v)).collect();
                let wide: Vec<f32> =
                    halves.iter().map(|&h| crate::util::f16_bits_to_f32(h)).collect();
                for b in [1usize, 3, 8, 11] {
                    let x: Vec<f32> = (0..b * ind).map(|_| rng.next_f32() - 0.3).collect();
                    let bias: Vec<f32> = (0..outd).map(|_| rng.next_f32()).collect();
                    let mut want = vec![0.0f32; b * outd];
                    csr_spmm_bias_fwd(Exec::Serial, &x, b, &topo, &vals, &bias, &mut want, &mut s);
                    let mut want16 = vec![0.0f32; b * outd];
                    csr_spmm_bias_fwd(
                        Exec::Serial, &x, b, &topo, &wide, &bias, &mut want16, &mut s,
                    );
                    let pool = crate::pool::KernelPool::with_par_min_ops(4, 1);
                    for exec in [Exec::Serial, Exec::Pool(&pool)] {
                        let pw = PackedFwd {
                            idx: &idx,
                            cb_byte: &cb_byte,
                            max_row,
                            vals: PackedValsRef::F32(&vals),
                        };
                        let mut y = vec![9.0f32; b * outd];
                        packed_spmm_bias_fwd(exec, &x, b, &topo, &pw, &bias, &mut y, &mut s);
                        for (a, e) in y.iter().zip(&want) {
                            assert_eq!(a.to_bits(), e.to_bits(), "f32 b={b} target={target}");
                        }
                        let pw = PackedFwd {
                            idx: &idx,
                            cb_byte: &cb_byte,
                            max_row,
                            vals: PackedValsRef::F16(&halves),
                        };
                        let mut y = vec![9.0f32; b * outd];
                        packed_spmm_bias_fwd(exec, &x, b, &topo, &pw, &bias, &mut y, &mut s);
                        for (a, e) in y.iter().zip(&want16) {
                            assert_eq!(a.to_bits(), e.to_bits(), "f16 b={b} target={target}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn back_dx_matches_dense_oracle() {
        let mut rng = Rng::new(2);
        let mut s = PanelScratch::default();
        let (b, ind, outd) = (9, 7, 4);
        let (w, topo) = setup(&mut rng, ind, outd, 0.5);
        let dy: Vec<f32> = (0..b * outd).map(|_| rng.next_f32() - 0.5).collect();
        let mut dx = vec![9.0f32; b * ind];
        spmm_back_dx(Exec::Serial, &dy, b, &topo, &w, &mut dx, &mut s);
        // dx = dy · Wᵀ
        let mut want = vec![0.0f32; b * ind];
        for bi in 0..b {
            for i in 0..ind {
                for o in 0..outd {
                    want[bi * ind + i] += w[i * outd + o] * dy[bi * outd + o];
                }
            }
        }
        for (a, e) in dx.iter().zip(&want) {
            assert!((a - e).abs() < 1e-5);
        }
    }

    #[test]
    fn back_dw_matches_outer_product_at_active_positions() {
        let mut rng = Rng::new(3);
        let mut s = PanelScratch::default();
        let (b, ind, outd) = (9, 5, 6);
        let (_, topo) = setup(&mut rng, ind, outd, 0.4);
        let x: Vec<f32> = (0..b * ind).map(|_| rng.next_f32() - 0.5).collect();
        let dy: Vec<f32> = (0..b * outd).map(|_| rng.next_f32() - 0.5).collect();
        let mut dw_vals = vec![0.0f32; topo.nnz()];
        spmm_back_dw(Exec::Serial, &x, &dy, b, &topo, &mut dw_vals, &mut s);
        let mut dense = vec![0.0f32; ind * outd];
        dense_back_dw(Exec::Serial, &x, &dy, b, ind, outd, &mut dense, &mut s);
        for i in 0..ind {
            for (k, &c) in topo.row(i).iter().enumerate() {
                let kk = topo.row_ptr[i] as usize + k;
                let want = dense[i * outd + c as usize];
                assert!((dw_vals[kk] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn softmax_xent_against_finite_differences() {
        let mut rng = Rng::new(4);
        let (b, k) = (3, 5);
        let logits: Vec<f32> = (0..b * k).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.next_below(k) as i32).collect();
        for &s in &[0.0f32, 0.1] {
            let mut d = vec![0.0f32; b * k];
            let loss = softmax_xent_grad(&logits, b, k, &y, s, &mut d);
            assert!(loss.is_finite() && loss > 0.0);
            let eps = 1e-3f32;
            for j in 0..b * k {
                let mut lp = logits.clone();
                lp[j] += eps;
                let mut scratch = vec![0.0f32; b * k];
                let lplus = softmax_xent_grad(&lp, b, k, &y, s, &mut scratch);
                lp[j] -= 2.0 * eps;
                let lminus = softmax_xent_grad(&lp, b, k, &y, s, &mut scratch);
                let fd = ((lplus - lminus) / (2.0 * eps as f64)) as f32;
                assert!(
                    (d[j] - fd).abs() < 2e-3,
                    "smoothing={s} j={j}: analytic {} vs fd {fd}",
                    d[j]
                );
            }
        }
    }

    #[test]
    fn xent_metrics_counts_correct_and_sums_nats() {
        // Two samples: one confidently right, one wrong.
        let logits = [5.0f32, 0.0, 0.0, /* s2 */ 0.0, 0.0, 5.0];
        let y = [0i32, 0];
        let (nll, correct) = xent_metrics(&logits, 2, 3, &y);
        assert_eq!(correct, 1.0);
        // s1 nll ≈ ln(1 + 2e^-5) ≈ 0.0134; s2 nll ≈ 5 + ln(1+2e^-5).
        assert!((nll - (0.013434 + 5.013434)).abs() < 1e-3, "{nll}");
    }

    #[test]
    fn sgdm_sparse_matches_reference_formula() {
        let mask = [1.0f32, 0.0, 1.0, 1.0];
        let topo = CsrTopo::from_mask(&mask, 2, 2);
        let mut w = [1.0f32, 0.0, -2.0, 0.5];
        let mut v = [0.1f32, 0.0, 0.0, -0.2];
        let dw_vals = [0.3f32, 0.4, 0.5]; // entries (0,0) (1,0) (1,1)
        let (lr, mu, wd) = (0.1f32, 0.9f32, 0.01f32);
        sgdm_update_sparse(Exec::Serial, &topo, &mut w, &mut v, &dw_vals, lr, mu, wd);
        // (0,0): g=0.3+0.01·1=0.31, v=0.09+0.31=0.4, w=1−0.04=0.96
        assert!((v[0] - 0.4).abs() < 1e-6);
        assert!((w[0] - 0.96).abs() < 1e-6);
        // masked entry untouched
        assert_eq!(w[1], 0.0);
        assert_eq!(v[1], 0.0);
        // (1,1): g=0.5+0.005=0.505, v=−0.18+0.505=0.325, w=0.5−0.0325
        assert!((v[3] - 0.325).abs() < 1e-6);
        assert!((w[3] - 0.4675).abs() < 1e-6);
    }

    #[test]
    fn relu_roundtrip() {
        let mut h = [1.0f32, -2.0, 0.0, 3.0];
        relu(&mut h);
        assert_eq!(h, [1.0, 0.0, 0.0, 3.0]);
        let mut dh = [5.0f32, 5.0, 5.0, 5.0];
        relu_bwd(&mut dh, &h);
        assert_eq!(dh, [5.0, 0.0, 0.0, 5.0]);
    }

    // ---------------------------------------------------------------
    // Parallel vs serial bit-identity. Pools here pin the autotune
    // floor to 1 so the blocked paths genuinely engage regardless of
    // this machine's measured round cost, and blocks are built with
    // small targets to force many work units.
    // ---------------------------------------------------------------

    /// A layer big enough to be worth the sweep, with blocks forced.
    fn big_setup(rng: &mut Rng, density: f64) -> (usize, usize, Vec<f32>, CsrTopo) {
        let (ind, outd) = (96usize, 80usize);
        let (w, mut topo) = setup(rng, ind, outd, density);
        topo.build_blocks_with(256, 8); // force multi-block decomposition
        (ind, outd, w, topo)
    }

    fn pinned_pool(threads: usize) -> KernelPool {
        KernelPool::with_par_min_ops(threads, 1)
    }

    #[test]
    fn parallel_forward_bit_identical_to_serial_any_threads() {
        let mut rng = Rng::new(0xF00);
        let mut s = PanelScratch::default();
        for &density in &[0.1f64, 0.6, 1.0] {
            let (ind, outd, w, topo) = big_setup(&mut rng, density);
            // 11 = one full panel + a ragged 3-row tail.
            let batch = 11;
            let x: Vec<f32> = (0..batch * ind).map(|_| rng.next_f32() - 0.4).collect();
            let bias: Vec<f32> = (0..outd).map(|_| rng.next_f32()).collect();
            let mut vals = Vec::with_capacity(topo.nnz());
            for i in 0..ind {
                for &c in topo.row(i) {
                    vals.push(w[i * outd + c as usize]);
                }
            }
            let mut y_ser = vec![0.0f32; batch * outd];
            spmm_bias_fwd(Exec::Serial, &x, batch, &topo, &w, &bias, &mut y_ser, &mut s);
            // The serial panel path must equal the scalar reference...
            let mut y_ref = vec![0.0f32; batch * outd];
            reference::spmm_bias_fwd(&x, batch, &topo, &w, &bias, &mut y_ref);
            for (a, e) in y_ser.iter().zip(&y_ref) {
                assert_eq!(a.to_bits(), e.to_bits(), "panel vs reference S={density}");
            }
            // ...and every pooled run must equal the serial run.
            for threads in [2usize, 3, 8] {
                let pool = pinned_pool(threads);
                let mut y_par = vec![7.0f32; batch * outd];
                spmm_bias_fwd(Exec::Pool(&pool), &x, batch, &topo, &w, &bias, &mut y_par, &mut s);
                for (a, e) in y_par.iter().zip(&y_ser) {
                    assert_eq!(a.to_bits(), e.to_bits(), "t={threads} S={density}");
                }
                let mut y_csr = vec![-3.0f32; batch * outd];
                csr_spmm_bias_fwd(
                    Exec::Pool(&pool),
                    &x,
                    batch,
                    &topo,
                    &vals,
                    &bias,
                    &mut y_csr,
                    &mut s,
                );
                for (a, e) in y_csr.iter().zip(&y_ser) {
                    assert_eq!(a.to_bits(), e.to_bits(), "csr t={threads} S={density}");
                }
            }
        }
    }

    #[test]
    fn parallel_backwards_bit_identical_to_serial() {
        let mut rng = Rng::new(0xF01);
        let mut s = PanelScratch::default();
        let (ind, outd, w, topo) = big_setup(&mut rng, 0.5);
        let batch = 11;
        let x: Vec<f32> = (0..batch * ind)
            .map(|_| if rng.next_f64() < 0.3 { 0.0 } else { rng.next_f32() })
            .collect();
        let dy: Vec<f32> = (0..batch * outd).map(|_| rng.next_f32() - 0.5).collect();

        let mut dx_ser = vec![0.0f32; batch * ind];
        spmm_back_dx(Exec::Serial, &dy, batch, &topo, &w, &mut dx_ser, &mut s);
        let mut dw_ser = vec![0.0f32; topo.nnz()];
        spmm_back_dw(Exec::Serial, &x, &dy, batch, &topo, &mut dw_ser, &mut s);
        let mut dd_ser = vec![0.0f32; ind * outd];
        dense_back_dw(Exec::Serial, &x, &dy, batch, ind, outd, &mut dd_ser, &mut s);

        // Panel paths equal the scalar references...
        let mut dx_ref = vec![0.0f32; batch * ind];
        reference::spmm_back_dx(&dy, batch, &topo, &w, &mut dx_ref);
        let mut dw_ref = vec![0.0f32; topo.nnz()];
        reference::spmm_back_dw(&x, &dy, batch, &topo, &mut dw_ref);
        let mut dd_ref = vec![0.0f32; ind * outd];
        reference::dense_back_dw(&x, &dy, batch, ind, outd, &mut dd_ref);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dx_ser), bits(&dx_ref), "dx panel vs reference");
        assert_eq!(bits(&dw_ser), bits(&dw_ref), "dw panel vs reference");
        assert_eq!(bits(&dd_ser), bits(&dd_ref), "dense panel vs reference");

        // ...and pooled runs equal serial runs.
        for threads in [2usize, 8] {
            let pool = pinned_pool(threads);
            let exec = Exec::Pool(&pool);
            let mut dx = vec![1.0f32; batch * ind];
            spmm_back_dx(exec, &dy, batch, &topo, &w, &mut dx, &mut s);
            let mut dw = vec![0.0f32; topo.nnz()];
            spmm_back_dw(exec, &x, &dy, batch, &topo, &mut dw, &mut s);
            let mut dd = vec![0.0f32; ind * outd];
            dense_back_dw(exec, &x, &dy, batch, ind, outd, &mut dd, &mut s);
            assert_eq!(bits(&dx), bits(&dx_ser), "dx t={threads}");
            assert_eq!(bits(&dw), bits(&dw_ser), "dw t={threads}");
            assert_eq!(bits(&dd), bits(&dd_ser), "dense t={threads}");
        }
    }

    #[test]
    fn parallel_sgdm_and_softmax_bit_identical_to_serial() {
        let mut rng = Rng::new(0xF02);
        let mut scratch = PanelScratch::default();
        let (ind, outd, w0, topo) = big_setup(&mut rng, 0.6);
        let v0: Vec<f32> = (0..ind * outd).map(|_| rng.next_f32() * 0.1).collect();
        let dw: Vec<f32> = (0..topo.nnz()).map(|_| rng.next_f32() - 0.5).collect();
        let (mut w_ser, mut v_ser) = (w0.clone(), v0.clone());
        sgdm_update_sparse(Exec::Serial, &topo, &mut w_ser, &mut v_ser, &dw, 0.1, 0.9, 1e-4);
        let (mut w_ref, mut v_ref) = (w0.clone(), v0.clone());
        reference::sgdm_update_sparse(&topo, &mut w_ref, &mut v_ref, &dw, 0.1, 0.9, 1e-4);
        let bits = |s: &[f32]| s.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&w_ser), bits(&w_ref), "sgdm lanes vs reference");
        assert_eq!(bits(&v_ser), bits(&v_ref), "sgdm moments lanes vs reference");
        for threads in [2usize, 8] {
            let pool = pinned_pool(threads);
            let (mut w, mut v) = (w0.clone(), v0.clone());
            sgdm_update_sparse(Exec::Pool(&pool), &topo, &mut w, &mut v, &dw, 0.1, 0.9, 1e-4);
            assert_eq!(bits(&w), bits(&w_ser), "w t={threads}");
            assert_eq!(bits(&v), bits(&v_ser), "v t={threads}");
        }

        // Softmax: full panels plus a ragged row, against the serial
        // reference and across thread counts.
        let (batch, classes) = (67usize, 40usize);
        let logits: Vec<f32> = (0..batch * classes).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let y: Vec<i32> = (0..batch).map(|_| rng.next_below(classes) as i32).collect();
        for &s in &[0.0f32, 0.1] {
            let mut d_ser = vec![0.0f32; batch * classes];
            let l_ser = softmax_xent_grad(&logits, batch, classes, &y, s, &mut d_ser);
            for threads in [1usize, 2, 8] {
                let pool = pinned_pool(threads);
                let exec = if threads == 1 { Exec::Serial } else { Exec::Pool(&pool) };
                let mut d = vec![5.0f32; batch * classes];
                let mut row_loss = vec![0.0f64; batch];
                let l = softmax_xent_grad_par(
                    exec,
                    &logits,
                    batch,
                    classes,
                    &y,
                    s,
                    &mut d,
                    &mut row_loss,
                    &mut scratch,
                );
                assert_eq!(l.to_bits(), l_ser.to_bits(), "loss t={threads} s={s}");
                for (a, e) in d.iter().zip(&d_ser) {
                    assert_eq!(a.to_bits(), e.to_bits());
                }
            }
        }
    }

    #[test]
    fn pool_exec_without_blocks_falls_back_cleanly() {
        // A topology that never had build_blocks called still executes
        // correctly (panel-serial) under a pool exec.
        let mut rng = Rng::new(0xF03);
        let mut s = PanelScratch::default();
        let (w, topo) = setup(&mut rng, 96, 80, 0.5);
        assert!(!topo.blocks.is_built());
        let batch = 8;
        let x: Vec<f32> = (0..batch * 96).map(|_| rng.next_f32()).collect();
        let bias = vec![0.1f32; 80];
        let mut y_ser = vec![0.0f32; batch * 80];
        reference::spmm_bias_fwd(&x, batch, &topo, &w, &bias, &mut y_ser);
        let pool = pinned_pool(4);
        let mut y_par = vec![0.0f32; batch * 80];
        spmm_bias_fwd(Exec::Pool(&pool), &x, batch, &topo, &w, &bias, &mut y_par, &mut s);
        for (a, e) in y_par.iter().zip(&y_ser) {
            assert_eq!(a.to_bits(), e.to_bits());
        }
    }

    /// Zero-heavy activations (the post-ReLU regime the skip paths
    /// exist for): whole-batch-zero input columns, per-lane zeros, and
    /// negative zeros must all take the skips without diverging from
    /// the scalar reference.
    #[test]
    fn skip_paths_bit_identical_under_zero_heavy_activations() {
        let mut rng = Rng::new(0xF04);
        let mut s = PanelScratch::default();
        let (ind, outd, w, topo) = big_setup(&mut rng, 0.4);
        let batch = 19; // 2 panels + 3-row tail
        let mut x: Vec<f32> = (0..batch * ind)
            .map(|_| if rng.next_f64() < 0.5 { 0.0 } else { rng.next_f32() })
            .collect();
        for i in 0..ind {
            if i % 7 == 0 {
                for b in 0..batch {
                    x[b * ind + i] = 0.0; // all-lane-zero rows
                }
            }
            if i % 11 == 0 {
                x[i] = -0.0; // negative zero must still be skipped
            }
        }
        let bias: Vec<f32> = (0..outd).map(|_| rng.next_f32() - 0.5).collect();
        let dy: Vec<f32> = (0..batch * outd).map(|_| rng.next_f32() - 0.5).collect();

        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        let mut y = vec![0.0f32; batch * outd];
        spmm_bias_fwd(Exec::Serial, &x, batch, &topo, &w, &bias, &mut y, &mut s);
        let mut y_ref = vec![0.0f32; batch * outd];
        reference::spmm_bias_fwd(&x, batch, &topo, &w, &bias, &mut y_ref);
        assert_eq!(bits(&y), bits(&y_ref), "fwd under zero-heavy x");

        let mut dw = vec![0.0f32; topo.nnz()];
        spmm_back_dw(Exec::Serial, &x, &dy, batch, &topo, &mut dw, &mut s);
        let mut dw_ref = vec![0.0f32; topo.nnz()];
        reference::spmm_back_dw(&x, &dy, batch, &topo, &mut dw_ref);
        assert_eq!(bits(&dw), bits(&dw_ref), "dw under zero-heavy x");

        let mut dd = vec![0.0f32; ind * outd];
        dense_back_dw(Exec::Serial, &x, &dy, batch, ind, outd, &mut dd, &mut s);
        let mut dd_ref = vec![0.0f32; ind * outd];
        reference::dense_back_dw(&x, &dy, batch, ind, outd, &mut dd_ref);
        assert_eq!(bits(&dd), bits(&dd_ref), "dense dw under zero-heavy x");
    }

    // NOTE: the panels-on/off equality property is deliberately NOT
    // tested here: flipping the global switch would race sibling lib
    // tests into the scalar path and silently weaken their coverage.
    // It lives in tests/simd_determinism.rs behind that binary's mutex
    // (whole-RigL-run panels-on/off bit-identity).
}
