//! Pluggable execution backends.
//!
//! The trainer's inner loop needs exactly four operations: a masked
//! optimizer step, dense gradients (RigL's grow signal), a per-batch eval
//! metric, and a way to keep any backend-private sparse views in sync
//! with the masks. Everything else (data, schedules, topology, FLOPs
//! accounting) is backend-agnostic. This module captures that contract
//! as the [`Backend`]/[`Session`] trait pair with two implementations:
//!
//! * [`pjrt`] — a thin adapter over the `runtime` module: state is
//!   uploaded as PJRT literals per call, the AOT HLO artifacts execute
//!   the step, and outputs are downloaded back into the host-side
//!   `ParamSet`s. Dense math, any model in the zoo. Compiled only with
//!   the `pjrt` cargo feature (the default).
//! * [`native`] — a pure-Rust, std-only sparse engine for the FC tracks:
//!   masked layers execute as CSR sparse×dense products, so per-step
//!   cost is proportional to nnz rather than to the dense parameter
//!   count, and nothing outside this crate (no XLA install, no AOT
//!   artifacts) is needed. Build with `--no-default-features` to get a
//!   fully hermetic binary.
//!
//! ## Ownership and state
//!
//! Host memory is canonical: all training state lives in the caller's
//! [`TrainState`] (`Vec<f32>` per tensor) and backends are stateless
//! between calls *except* for per-run derived views. Those views live in
//! a [`Session`]:
//!
//! * a `Backend` is immutable and `Send + Sync` — one per model, shared
//!   across the coordinator's worker threads via the `Trainer`;
//! * a `Session` is per-run and mutable — it owns whatever the backend
//!   derives from the masks (the native engine's CSR topologies and
//!   activation buffers; nothing for PJRT). Sessions are cheap to open
//!   for PJRT and O(params) for native (one CSR build), after which mask
//!   changes are patched **incrementally** via [`Session::masks_updated`]
//!   with the exact drop/grow lists from
//!   [`topology::update_masks_visit`](crate::topology::update_masks_visit).
//!
//! A session's sparse views mirror `state.masks` at all times: callers
//! that replace masks wholesale (SNIP's one-shot mask, gradual pruning)
//! must call [`Session::resync`] afterwards.

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub mod native;

use anyhow::Result;

use crate::model::{load_manifest, Manifest, ParamSet};
use crate::train::{Batch, TrainState};

/// The model manifest a backend trains from: the AOT artifacts manifest
/// when present, else (native only, and only when the manifest is
/// genuinely *absent* — a present-but-corrupt one still propagates its
/// parse error) the built-in FC model zoo. The one fallback rule shared
/// by the CLI and the experiment coordinator.
pub fn manifest_for(kind: BackendKind) -> Result<Manifest> {
    match load_manifest(&crate::artifacts_dir()) {
        Ok(m) => Ok(m),
        Err(e) if kind == BackendKind::Native && is_not_found(&e) => {
            Ok(native::builtin_manifest())
        }
        Err(e) => Err(e),
    }
}

fn is_not_found(e: &anyhow::Error) -> bool {
    e.root_cause()
        .downcast_ref::<std::io::Error>()
        .is_some_and(|io| io.kind() == std::io::ErrorKind::NotFound)
}

/// Which engine executes the training math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT HLO artifacts through the PJRT runtime (requires `make
    /// artifacts` and the `pjrt` cargo feature).
    Pjrt,
    /// The pure-Rust CSR engine (FC classify models, SGD+momentum).
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pjrt" => BackendKind::Pjrt,
            "native" => BackendKind::Native,
            _ => anyhow::bail!("unknown backend {s:?} (pjrt|native)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
        }
    }
}

/// An immutable, thread-shareable execution engine for one model.
pub trait Backend: Send + Sync {
    fn kind(&self) -> BackendKind;

    /// Open a per-run session whose derived views mirror the given
    /// state's masks. The returned session borrows the backend only —
    /// it holds no reference to `state`, so callers keep full mutable
    /// access to their training state between calls.
    fn session<'b>(&'b self, state: &TrainState) -> Result<Box<dyn Session + 'b>>;
}

/// Per-run mutable execution context (buffers + sparse views).
///
/// Every method takes the state explicitly: upload/download of whatever
/// device- or layout-specific buffers the backend uses happens inside
/// the call, and the host `TrainState` is authoritative before and
/// after.
pub trait Session {
    /// One masked optimizer step (`params/opt` updated in place);
    /// returns the training loss. Mirrors the `train` AOT artifact.
    fn train_step(
        &mut self,
        state: &mut TrainState,
        x: &Batch,
        y: &[i32],
        lr: f32,
    ) -> Result<f64>;

    /// Dense gradients ∇_Θ L as a full `ParamSet` (zeros on
    /// non-sparsifiable tensors) plus the loss. Mirrors `densegrad`.
    fn dense_grads(&mut self, state: &TrainState, x: &Batch, y: &[i32])
        -> Result<(ParamSet, f64)>;

    /// One eval batch → `(metric_sum, count)`: classify = (Σ plain
    /// cross-entropy, Σ correct); lm = (Σ nats, token count). Mirrors
    /// `eval`.
    fn eval_batch(&mut self, state: &TrainState, x: &Batch, y: &[i32]) -> Result<(f64, f64)>;

    /// Incremental structural patch after a topology update on spec
    /// `li`: the layer's new active set is `(active \ dropped) ∪ grown`
    /// (flat element indices). Backends without derived sparse views
    /// ignore this.
    fn masks_updated(&mut self, li: usize, dropped: &[u32], grown: &[u32]) {
        let _ = (li, dropped, grown);
    }

    /// Full rebuild of derived views after a wholesale mask replacement
    /// (SNIP init, gradual-pruning events).
    fn resync(&mut self, state: &TrainState) {
        let _ = state;
    }
}
