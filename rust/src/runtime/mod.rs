//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. HLO
//! *text* is the interchange format — see python/compile/aot.py and
//! /opt/xla-example/README.md for why serialized protos are rejected.
//!
//! Compiled executables are cached per path, so the coordinator can spin
//! up many `Trainer`s against the same `Runtime` without recompiling.

mod literals;

pub use literals::{lit_f32, lit_i32, lit_scalar_f32, to_vec_f32};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

/// A PJRT client plus an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<PathBuf, Rc<Executable>>>,
    /// Cumulative compile time, reported by `repro bench`-style harnesses.
    pub compile_seconds: RefCell<f64>,
}

impl Runtime {
    /// CPU PJRT client (the only backend in this testbed).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: RefCell::new(HashMap::new()),
            compile_seconds: RefCell::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<Rc<Executable>> {
        if let Some(exe) = self.cache.borrow().get(path) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        *self.compile_seconds.borrow_mut() += t0.elapsed().as_secs_f64();
        let exe = Rc::new(Executable {
            exe,
            path: path.to_path_buf(),
        });
        self.cache
            .borrow_mut()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }
}

/// One compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

impl Executable {
    /// Execute with literal inputs; decompose the (return_tuple=True) root
    /// tuple into one literal per output.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {:?}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {:?}", self.path))?;
        lit.to_tuple().map_err(Into::into)
    }

    /// Execute and read every output back as f32 vectors.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?.iter().map(to_vec_f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::load_manifest;

    /// Shared runtime for tests (PJRT client startup is expensive).
    fn runtime() -> Runtime {
        Runtime::cpu().unwrap()
    }

    #[test]
    fn cpu_client_up() {
        let rt = runtime();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn load_and_run_mlp_eval() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = load_manifest(&dir).unwrap();
        let def = m.get("mlp").unwrap();
        let rt = runtime();
        let exe = rt.load(&m.artifact_path("mlp", "eval").unwrap()).unwrap();

        // params (zeros) + masks (ones) + x + y → (loss_sum, correct).
        let mut inputs = Vec::new();
        for s in &def.specs {
            inputs.push(lit_f32(&vec![0.0; s.size()], &s.dims_i64()).unwrap());
        }
        for s in &def.specs {
            inputs.push(lit_f32(&vec![1.0; s.size()], &s.dims_i64()).unwrap());
        }
        let b = def.batch_size();
        inputs.push(lit_f32(&vec![0.0; b * 784], &[b as i64, 784]).unwrap());
        inputs.push(lit_i32(&vec![0; b], &[b as i64]).unwrap());
        let out = exe.run_f32(&inputs).unwrap();
        assert_eq!(out.len(), 2);
        // Zero params ⇒ uniform logits ⇒ loss = B·ln(10).
        let expect = b as f32 * (10f32).ln();
        assert!(
            (out[0][0] - expect).abs() < 1e-2,
            "loss_sum {} vs {expect}",
            out[0][0]
        );
    }

    #[test]
    fn executable_cache_hits() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let m = load_manifest(&dir).unwrap();
        let rt = runtime();
        let p = m.artifact_path("mlp", "eval").unwrap();
        let a = rt.load(&p).unwrap();
        let secs = *rt.compile_seconds.borrow();
        let b = rt.load(&p).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        assert_eq!(*rt.compile_seconds.borrow(), secs, "second load must not compile");
    }
}
