//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (docs.rs/xla 0.1.6): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. HLO
//! *text* is the interchange format — see python/compile/aot.py and
//! /opt/xla-example/README.md for why serialized protos are rejected.
//!
//! Compiled executables are cached per path, so the coordinator can spin
//! up many `Trainer`s against the same `Runtime` without recompiling.
//!
//! ## Concurrency model
//!
//! `Runtime` and `Executable` are shared across the coordinator's worker
//! threads: every `Trainer` holds `Arc<Executable>`s and many trainers
//! run concurrently under `pool::par_map`. The PJRT C API specifies that
//! clients and loaded executables are thread-safe — `Compile` and
//! `Execute` may be invoked concurrently from any thread (each `Execute`
//! owns its own output buffers). The `xla` crate wraps raw C++ pointers
//! and therefore does not *derive* `Send`/`Sync`, so this module asserts
//! them explicitly on the two owning types below.
//!
//! The only interior mutability is the executable cache and the
//! cumulative compile-time counter, both behind one `Mutex`. The lock is
//! deliberately held **across compilation**: concurrent first-time loads
//! of the same artifact then compile exactly once, and PJRT compilation
//! (not specified reentrant by every plugin) is serialized. Execution
//! never takes the lock, so the training hot path is uncontended.

mod literals;

pub use literals::{lit_f32, lit_i32, lit_scalar_f32, to_vec_f32};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

/// A PJRT client plus an executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    /// Path → compiled executable. Guards the cache AND serializes
    /// compilation (see module docs).
    cache: Mutex<HashMap<PathBuf, Arc<Executable>>>,
    /// Cumulative compile time, reported by `repro bench`-style harnesses.
    compile_seconds: Mutex<f64>,
}

// SAFETY: `xla::PjRtClient` is a shared handle to a PJRT C-API client.
// The PJRT contract requires clients to be thread-safe (compilation and
// buffer creation from arbitrary threads); the CPU plugin used here
// honors it. The `xla` crate does not declare this itself because its
// inner type is a raw pointer. All Rust-side mutable state in `Runtime`
// is behind a `Mutex`.
//
// CAVEAT (validation debt): this soundness argument rests on the PJRT
// contract, not on an audit of the xla-rs 0.1.6 wrapper internals, and
// was authored in a container without a Rust toolchain. Before trusting
// `--jobs > 1` output, run the serial-vs-parallel integration test on a
// toolchain-equipped machine (ideally under ThreadSanitizer) — see
// ROADMAP.md "Open items". `--jobs 1` stays on the strictly serial path.
//
// VERDICT LOG: 2026-07-28 (backend-subsystem PR) — attempted; the
// container again ships no cargo/rustc, so the equivalence test and
// TSan run remain UNEXECUTED and this Send/Sync assertion remains
// unvalidated. Two mitigations landed in that PR: the whole module is
// now behind the `pjrt` cargo feature (a `--no-default-features` build
// carries no unsafe at all), and `--backend native` offers a PJRT-free
// execution path whose thread-safety is ordinary safe Rust.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// CPU PJRT client (the only backend in this testbed).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Cumulative seconds spent compiling artifacts on this runtime.
    pub fn compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }

    /// Load + compile an HLO-text artifact (cached; compile-once even
    /// under concurrent callers).
    pub fn load(&self, path: &Path) -> Result<Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(path) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        *self.compile_seconds.lock().unwrap() += t0.elapsed().as_secs_f64();
        let exe = Arc::new(Executable {
            exe,
            path: path.to_path_buf(),
        });
        cache.insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }
}

/// One compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub path: PathBuf,
}

// SAFETY: PJRT loaded executables are immutable after compilation and
// the PJRT contract allows concurrent `Execute` calls; each call returns
// freshly-allocated output buffers. `run` takes `&self` only.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with literal inputs; decompose the (return_tuple=True) root
    /// tuple into one literal per output.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {:?}", self.path))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {:?}", self.path))?;
        lit.to_tuple().map_err(Into::into)
    }

    /// Execute and read every output back as f32 vectors.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?.iter().map(to_vec_f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::load_manifest;

    /// Shared runtime for tests (PJRT client startup is expensive).
    fn runtime() -> Runtime {
        Runtime::cpu().unwrap()
    }

    #[test]
    fn cpu_client_up() {
        let rt = runtime();
        assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    }

    #[test]
    fn load_and_run_mlp_eval() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = load_manifest(&dir).unwrap();
        let def = m.get("mlp").unwrap();
        let rt = runtime();
        let exe = rt.load(&m.artifact_path("mlp", "eval").unwrap()).unwrap();

        // params (zeros) + masks (ones) + x + y → (loss_sum, correct).
        let mut inputs = Vec::new();
        for s in &def.specs {
            inputs.push(lit_f32(&vec![0.0; s.size()], &s.dims_i64()).unwrap());
        }
        for s in &def.specs {
            inputs.push(lit_f32(&vec![1.0; s.size()], &s.dims_i64()).unwrap());
        }
        let b = def.batch_size();
        inputs.push(lit_f32(&vec![0.0; b * 784], &[b as i64, 784]).unwrap());
        inputs.push(lit_i32(&vec![0; b], &[b as i64]).unwrap());
        let out = exe.run_f32(&inputs).unwrap();
        assert_eq!(out.len(), 2);
        // Zero params ⇒ uniform logits ⇒ loss = B·ln(10).
        let expect = b as f32 * (10f32).ln();
        assert!(
            (out[0][0] - expect).abs() < 1e-2,
            "loss_sum {} vs {expect}",
            out[0][0]
        );
    }

    #[test]
    fn executable_cache_hits() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let m = load_manifest(&dir).unwrap();
        let rt = runtime();
        let p = m.artifact_path("mlp", "eval").unwrap();
        let a = rt.load(&p).unwrap();
        let secs = rt.compile_seconds();
        let b = rt.load(&p).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(rt.compile_seconds(), secs, "second load must not compile");
    }

    #[test]
    fn runtime_is_send_and_sync() {
        // Compile-time guarantee the coordinator's thread pool relies on.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
        assert_send_sync::<Executable>();
        assert_send_sync::<Arc<Executable>>();
    }
}
