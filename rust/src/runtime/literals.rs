//! Host ↔ XLA literal marshalling helpers.

use anyhow::Result;

/// f32 literal with the given dims (row-major).
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    debug_assert_eq!(
        data.len() as i64,
        dims.iter().product::<i64>().max(1),
        "lit_f32 shape mismatch"
    );
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(Into::into)
}

/// i32 literal with the given dims.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(Into::into)
}

/// Scalar f32 literal.
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read any f32 literal back to a host vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(Into::into)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
    }

    #[test]
    fn scalar() {
        let lit = lit_scalar_f32(2.5);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![2.5]);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3];
        let lit = lit_i32(&data, &[3]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }
}
