//! Batch-panel SIMD determinism suite: the panel kernels must be a pure
//! wall-clock knob, like threads and block layout before them.
//!
//! For EVERY vectorized kernel the panel path is asserted BIT-identical
//! to the scalar reference (`kernels::reference`) across batch sizes
//! {1..9, 16, 33} (full panels, ragged tails, no panels at all),
//! sparsities {0.98, 0.5, 0.0}, and threads {1, 8}; a whole RigL
//! training run is asserted bit-identical with panels on vs off; and —
//! when the `simd-intrinsics` feature is compiled in — the AVX2 ops are
//! asserted bit-identical to the portable ops on the same grid.
//!
//! Hermetic: models built in code, synthetic data, no artifacts, no
//! PJRT — runs on the `--no-pjrt` CI path. Pools pin their autotune
//! floor to 1 so the pooled paths genuinely engage on any machine.
//!
//! Tests serialize on a process-local mutex: several of them flip the
//! global panel switch (`set_panel_kernels`) or, under the feature, the
//! force-portable override, and interleaving would make a neighbouring
//! comparison vacuous (never wrong — both sides always agree — just
//! weaker than intended).

use std::sync::{Mutex, MutexGuard};

use rigl::backend::native::csr::CsrTopo;
use rigl::backend::native::kernels::{self, reference, set_panel_kernels, Exec};
use rigl::backend::native::simd::PanelScratch;
use rigl::pool::KernelPool;
use rigl::util::Rng;

static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

const BATCHES: &[usize] = &[1, 2, 3, 4, 5, 6, 7, 8, 9, 16, 33];
const SPARSITIES: &[f64] = &[0.98, 0.5, 0.0];

/// One random masked layer with a forced multi-block decomposition and
/// zero-heavy activations (the post-ReLU regime the skip paths serve).
struct Layer {
    ind: usize,
    outd: usize,
    topo: CsrTopo,
    w: Vec<f32>,
    vals: Vec<f32>,
    bias: Vec<f32>,
}

fn layer(rng: &mut Rng, sparsity: f64) -> Layer {
    let (ind, outd) = (40usize, 28usize);
    let mut w = vec![0.0f32; ind * outd];
    let mut mask = vec![0.0f32; ind * outd];
    for (wi, mi) in w.iter_mut().zip(mask.iter_mut()) {
        if rng.next_f64() >= sparsity {
            *mi = 1.0;
            *wi = rng.next_f32() - 0.5;
        }
    }
    let mut topo = CsrTopo::from_mask(&mask, ind, outd);
    topo.build_blocks_with(16, 6); // force several row AND column blocks
    let mut vals = Vec::with_capacity(topo.nnz());
    for i in 0..ind {
        for &c in topo.row(i) {
            vals.push(w[i * outd + c as usize]);
        }
    }
    let bias: Vec<f32> = (0..outd).map(|_| rng.next_f32() - 0.5).collect();
    Layer { ind, outd, topo, w, vals, bias }
}

/// Zero-heavy input: ~40% exact zeros, some whole-batch-zero columns
/// (panel-level skip), an occasional negative zero.
fn zero_heavy(rng: &mut Rng, batch: usize, dim: usize) -> Vec<f32> {
    let mut x: Vec<f32> = (0..batch * dim)
        .map(|_| if rng.next_f64() < 0.4 { 0.0 } else { rng.next_f32() - 0.4 })
        .collect();
    for i in 0..dim {
        if i % 9 == 0 {
            for b in 0..batch {
                x[b * dim + i] = 0.0;
            }
        }
    }
    if dim > 1 {
        x[1] = -0.0;
    }
    x
}

/// Execution contexts for the sweep: serial, plus an 8-lane pool with
/// the autotune floor pinned so blocked paths always engage.
fn with_execs(f: impl Fn(Exec, &str)) {
    f(Exec::Serial, "threads=1");
    let pool = KernelPool::with_par_min_ops(8, 1);
    f(Exec::Pool(&pool), "threads=8");
}

#[test]
fn forward_panel_bitwise_equals_scalar_reference() {
    let _g = lock();
    let mut rng = Rng::new(0x51D0);
    for &s in SPARSITIES {
        let l = layer(&mut rng, s);
        for &batch in BATCHES {
            let x = zero_heavy(&mut rng, batch, l.ind);
            let mut want = vec![0.0f32; batch * l.outd];
            reference::spmm_bias_fwd(&x, batch, &l.topo, &l.w, &l.bias, &mut want);
            let mut want_csr = vec![0.0f32; batch * l.outd];
            reference::csr_spmm_bias_fwd(&x, batch, &l.topo, &l.vals, &l.bias, &mut want_csr);
            assert_eq!(bits(&want), bits(&want_csr), "reference dense vs csr S={s} b={batch}");
            with_execs(|exec, tag| {
                let mut scratch = PanelScratch::default();
                let mut y = vec![7.0f32; batch * l.outd];
                kernels::spmm_bias_fwd(
                    exec, &x, batch, &l.topo, &l.w, &l.bias, &mut y, &mut scratch,
                );
                assert_eq!(bits(&y), bits(&want), "fwd S={s} b={batch} {tag}");
                let mut yc = vec![-3.0f32; batch * l.outd];
                kernels::csr_spmm_bias_fwd(
                    exec, &x, batch, &l.topo, &l.vals, &l.bias, &mut yc, &mut scratch,
                );
                assert_eq!(bits(&yc), bits(&want), "csr fwd S={s} b={batch} {tag}");
            });
        }
    }
}

#[test]
fn backward_dx_panel_bitwise_equals_scalar_reference() {
    let _g = lock();
    let mut rng = Rng::new(0x51D1);
    for &s in SPARSITIES {
        let l = layer(&mut rng, s);
        for &batch in BATCHES {
            let dy: Vec<f32> = (0..batch * l.outd).map(|_| rng.next_f32() - 0.5).collect();
            let mut want = vec![0.0f32; batch * l.ind];
            reference::spmm_back_dx(&dy, batch, &l.topo, &l.w, &mut want);
            with_execs(|exec, tag| {
                let mut scratch = PanelScratch::default();
                let mut dx = vec![1.0f32; batch * l.ind];
                kernels::spmm_back_dx(exec, &dy, batch, &l.topo, &l.w, &mut dx, &mut scratch);
                assert_eq!(bits(&dx), bits(&want), "dx S={s} b={batch} {tag}");
            });
        }
    }
}

#[test]
fn backward_dw_panels_bitwise_equal_scalar_reference() {
    let _g = lock();
    let mut rng = Rng::new(0x51D2);
    for &s in SPARSITIES {
        let l = layer(&mut rng, s);
        for &batch in BATCHES {
            let x = zero_heavy(&mut rng, batch, l.ind);
            let dy: Vec<f32> = (0..batch * l.outd).map(|_| rng.next_f32() - 0.5).collect();
            let mut want = vec![0.0f32; l.topo.nnz()];
            reference::spmm_back_dw(&x, &dy, batch, &l.topo, &mut want);
            let mut want_dense = vec![0.0f32; l.ind * l.outd];
            reference::dense_back_dw(&x, &dy, batch, l.ind, l.outd, &mut want_dense);
            with_execs(|exec, tag| {
                let mut scratch = PanelScratch::default();
                let mut dw = vec![0.0f32; l.topo.nnz()];
                kernels::spmm_back_dw(exec, &x, &dy, batch, &l.topo, &mut dw, &mut scratch);
                assert_eq!(bits(&dw), bits(&want), "dw S={s} b={batch} {tag}");
                let mut dd = vec![0.0f32; l.ind * l.outd];
                kernels::dense_back_dw(
                    exec, &x, &dy, batch, l.ind, l.outd, &mut dd, &mut scratch,
                );
                assert_eq!(bits(&dd), bits(&want_dense), "dense dw S={s} b={batch} {tag}");
            });
        }
    }
}

#[test]
fn sgdm_lane_chunks_bitwise_equal_scalar_reference() {
    let _g = lock();
    let mut rng = Rng::new(0x51D3);
    for &s in SPARSITIES {
        let l = layer(&mut rng, s);
        let w0 = l.w.clone();
        let v0: Vec<f32> = (0..l.ind * l.outd).map(|_| rng.next_f32() * 0.1 - 0.05).collect();
        let dw: Vec<f32> = (0..l.topo.nnz()).map(|_| rng.next_f32() - 0.5).collect();
        let (lr, mu, wd) = (0.07f32, 0.9f32, 1e-4f32);
        let (mut w_ref, mut v_ref) = (w0.clone(), v0.clone());
        reference::sgdm_update_sparse(&l.topo, &mut w_ref, &mut v_ref, &dw, lr, mu, wd);
        with_execs(|exec, tag| {
            let (mut w, mut v) = (w0.clone(), v0.clone());
            kernels::sgdm_update_sparse(exec, &l.topo, &mut w, &mut v, &dw, lr, mu, wd);
            assert_eq!(bits(&w), bits(&w_ref), "sgdm w S={s} {tag}");
            assert_eq!(bits(&v), bits(&v_ref), "sgdm v S={s} {tag}");
        });
        // Dense (bias-shaped) update, ragged lengths around the lane width.
        for n in [1usize, 7, 8, 9, 16, 33] {
            let b0: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let m0: Vec<f32> = (0..n).map(|_| rng.next_f32() * 0.1).collect();
            let g: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let (mut b_ref, mut m_ref) = (b0.clone(), m0.clone());
            reference::sgdm_update_dense(&mut b_ref, &mut m_ref, &g, lr, mu, wd);
            let (mut b, mut m) = (b0.clone(), m0.clone());
            kernels::sgdm_update_dense(&mut b, &mut m, &g, lr, mu, wd);
            assert_eq!(bits(&b), bits(&b_ref), "sgdm dense n={n}");
            assert_eq!(bits(&m), bits(&m_ref), "sgdm dense moments n={n}");
        }
    }
}

#[test]
fn softmax_panel_bitwise_equals_scalar_reference() {
    let _g = lock();
    let mut rng = Rng::new(0x51D4);
    let classes = 13; // deliberately not a multiple of the lane width
    for &batch in BATCHES {
        let logits: Vec<f32> = (0..batch * classes).map(|_| rng.next_f32() * 6.0 - 3.0).collect();
        let y: Vec<i32> = (0..batch).map(|_| rng.next_below(classes) as i32).collect();
        for &sm in &[0.0f32, 0.1] {
            let mut d_ref = vec![0.0f32; batch * classes];
            let l_ref = reference::softmax_xent_grad(&logits, batch, classes, &y, sm, &mut d_ref);
            with_execs(|exec, tag| {
                let mut scratch = PanelScratch::default();
                let mut d = vec![5.0f32; batch * classes];
                let mut row_loss = vec![0.0f64; batch];
                let l = kernels::softmax_xent_grad_par(
                    exec, &logits, batch, classes, &y, sm, &mut d, &mut row_loss, &mut scratch,
                );
                assert_eq!(l.to_bits(), l_ref.to_bits(), "loss b={batch} sm={sm} {tag}");
                assert_eq!(bits(&d), bits(&d_ref), "dlogits b={batch} sm={sm} {tag}");
            });
        }
    }
}

/// One full RigL run (mask updates, CSR patching, evals included) with
/// the panel kernels forced on or off: final state and loss history as
/// bits.
fn run_rigl(panels: bool, threads: usize) -> (Vec<Vec<u32>>, Vec<u64>, u64, usize) {
    use std::sync::Arc;

    use rigl::backend::native::{mlp_def, NativeBackend};
    use rigl::topology::Method;
    use rigl::train::{TrainConfig, Trainer};

    let was = set_panel_kernels(panels);
    let mut cfg = TrainConfig::new("simd_mlp", Method::Rigl);
    cfg.sparsity = 0.9;
    cfg.steps = 60;
    cfg.delta_t = 20;
    cfg.augment = false;
    cfg.data_train = 512;
    cfg.data_val = 256;
    cfg.threads = threads;
    // Batch 32 = four full panels; hidden sizes chosen so one layer has
    // a ragged out_dim and per-row nnz straddles the lane width.
    let def = mlp_def(&cfg.model, 784, &[84, 44], 10, 32);
    let pool = (threads > 1).then(|| Arc::new(KernelPool::with_par_min_ops(threads, 1)));
    let backend = Arc::new(NativeBackend::with_pool(&def, pool).unwrap());
    let trainer = Trainer::from_parts(def, backend, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    set_panel_kernels(was);
    let tensors: Vec<Vec<u32>> = state
        .params
        .tensors
        .iter()
        .chain(state.opt[0].tensors.iter())
        .chain(state.masks.tensors.iter())
        .map(|t| bits(t))
        .collect();
    let losses: Vec<u64> = r.loss_history.iter().map(|(_, l)| l.to_bits()).collect();
    (tensors, losses, r.final_train_loss.to_bits(), r.total_swapped)
}

/// The headline property: an entire RigL training run — forward,
/// backward, optimizer, topology updates, CSR patching — is
/// bit-identical with the panel kernels on and off, serial and pooled.
#[test]
fn rigl_run_bit_identical_with_panels_on_or_off() {
    let _g = lock();
    let (t_off, l_off, fl_off, sw_off) = run_rigl(false, 1);
    for (panels, threads) in [(true, 1), (true, 2)] {
        let (t, l, fl, sw) = run_rigl(panels, threads);
        let tag = format!("panels={panels} threads={threads}");
        assert_eq!(sw, sw_off, "topology diverged ({tag})");
        assert_eq!(l, l_off, "loss history diverged ({tag})");
        assert_eq!(fl, fl_off, "final train loss diverged ({tag})");
        for (i, (a, b)) in t.iter().zip(&t_off).enumerate() {
            assert_eq!(a, b, "tensor {i} diverged ({tag})");
        }
    }
}

/// With the AVX2 feature compiled in, every kernel must produce the
/// same bits whether the intrinsics or the portable lane ops execute
/// (on machines without AVX2 both sides are portable — vacuous but
/// correct).
#[cfg(feature = "simd-intrinsics")]
#[test]
fn intrinsics_bitwise_equal_portable_for_every_kernel() {
    use rigl::backend::native::simd::set_force_portable;
    let _g = lock();
    let mut rng = Rng::new(0x51D5);
    for &s in &[0.5f64, 0.0] {
        let l = layer(&mut rng, s);
        let batch = 16;
        let x = zero_heavy(&mut rng, batch, l.ind);
        let dy: Vec<f32> = (0..batch * l.outd).map(|_| rng.next_f32() - 0.5).collect();
        let run_all = || {
            let mut scratch = PanelScratch::default();
            let mut y = vec![0.0f32; batch * l.outd];
            kernels::spmm_bias_fwd(
                Exec::Serial, &x, batch, &l.topo, &l.w, &l.bias, &mut y, &mut scratch,
            );
            let mut dx = vec![0.0f32; batch * l.ind];
            kernels::spmm_back_dx(Exec::Serial, &dy, batch, &l.topo, &l.w, &mut dx, &mut scratch);
            let mut dw = vec![0.0f32; l.topo.nnz()];
            kernels::spmm_back_dw(Exec::Serial, &x, &dy, batch, &l.topo, &mut dw, &mut scratch);
            let mut dd = vec![0.0f32; l.ind * l.outd];
            kernels::dense_back_dw(
                Exec::Serial, &x, &dy, batch, l.ind, l.outd, &mut dd, &mut scratch,
            );
            let (mut w, mut v) = (l.w.clone(), vec![0.01f32; l.ind * l.outd]);
            kernels::sgdm_update_sparse(Exec::Serial, &l.topo, &mut w, &mut v, &dw, 0.1, 0.9, 1e-4);
            (bits(&y), bits(&dx), bits(&dw), bits(&dd), bits(&w), bits(&v))
        };
        let fast = run_all();
        let was = set_force_portable(true);
        let slow = run_all();
        set_force_portable(was);
        assert_eq!(fast, slow, "intrinsics vs portable diverged at S={s}");
    }
}
