//! Observability contract suite: the `obs` subsystem must never change
//! numerics, never allocate on the steady-state record path, and
//! compile down to a relaxed load + branch when disabled.
//!
//! Everything here is hermetic (in-code models, synthetic data,
//! loopback servers) and serializes on a process-wide lock because the
//! tests toggle the *global* enable/arm flags — the library's own unit
//! tests never touch those flags, by convention, so this file is the
//! single place their semantics are exercised.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use rigl::backend::native::{mlp_def, NativeBackend};
use rigl::obs::{self, metrics, trace};
use rigl::pool::KernelPool;
use rigl::serve::{Client, ServeConfig, Server, SparseModel};
use rigl::sparsity::Distribution;
use rigl::topology::Method;
use rigl::train::{RunObs, TrainConfig, Trainer};
use rigl::util::Rng;

/// Counting allocator: the zero-steady-state-allocation gate is an
/// exact count of alloc + realloc events, not a heuristic (same
/// discipline as `bench_serve`). Dealloc is uncounted — dropping a
/// warm buffer is fine; *acquiring* one on the hot path is not.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_events() -> u64 {
    ALLOC_EVENTS.load(Ordering::Relaxed)
}

/// Process-wide serialization: these tests flip global flags, so they
/// must not interleave. Poison-tolerant — an assert failure in one
/// test must not cascade into every other test "failing" on a
/// poisoned lock.
static LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Restores the global enable/arm flags on drop, so a panicking test
/// cannot leak a disabled-obs or armed-trace state into its siblings.
struct FlagGuard {
    enabled: bool,
    armed: bool,
}

impl FlagGuard {
    fn set(enabled: bool, armed: bool) -> FlagGuard {
        FlagGuard { enabled: obs::set_enabled(enabled), armed: trace::set_armed(armed) }
    }
}

impl Drop for FlagGuard {
    fn drop(&mut self) {
        obs::set_enabled(self.enabled);
        trace::set_armed(self.armed);
    }
}

// ---------------------------------------------------------------------------
// Numerics: bit-identity with obs on / off / armed, serial and threaded.
// ---------------------------------------------------------------------------

fn small_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::new("obs_det_mlp", Method::Rigl);
    cfg.sparsity = 0.9;
    cfg.steps = 30;
    cfg.delta_t = 10;
    cfg.augment = false;
    cfg.data_train = 256;
    cfg.data_val = 128;
    cfg
}

/// One full RigL run; returns every parameter tensor as raw bits plus
/// the final train loss, so comparisons are exact, not approximate.
fn train_bits(obs_on: bool, threads: usize, arm_trace: bool) -> (Vec<Vec<u32>>, u64, RunObs) {
    let _flags = FlagGuard::set(obs_on, arm_trace);
    let cfg = small_cfg();
    let def = mlp_def(&cfg.model, 784, &[32], 10, 16);
    let pool = Arc::new(KernelPool::with_par_min_ops(threads, 1));
    let backend = Arc::new(NativeBackend::with_pool(&def, Some(pool)).unwrap());
    let trainer = Trainer::from_parts(def, backend, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    let bits = state
        .params
        .tensors
        .iter()
        .map(|t| t.iter().map(|v| v.to_bits()).collect())
        .collect();
    (bits, r.final_train_loss.to_bits(), r.obs)
}

#[test]
fn training_is_bit_identical_with_obs_on_off_and_armed() {
    let _g = serialize();
    let (base_bits, base_loss, _) = train_bits(true, 1, false);
    // Ordered as (obs enabled, kernel threads, trace armed).
    let cases = [(false, 1, false), (true, 8, false), (false, 8, false), (true, 1, true)];
    for (on, threads, armed) in cases {
        let (bits, loss, _) = train_bits(on, threads, armed);
        assert_eq!(
            bits, base_bits,
            "params diverged at obs={on} threads={threads} armed={armed}"
        );
        assert_eq!(
            loss, base_loss,
            "loss diverged at obs={on} threads={threads} armed={armed}"
        );
    }
}

#[test]
fn run_obs_populates_when_enabled_and_stays_zero_when_disabled() {
    let _g = serialize();
    let (_, _, on) = train_bits(true, 1, false);
    // steps=30, delta_t=10 → mask updates fired; phases were timed.
    assert!(on.updates >= 1, "no mask updates recorded: {on:?}");
    assert!(!on.nnz_start.is_empty() && !on.nnz_end.is_empty());
    assert_eq!(on.nnz_start.len(), on.nnz_end.len());
    assert!(on.train_step_s > 0.0, "train_step phase not timed");
    assert!(on.mask_update_s > 0.0, "mask_update phase not timed");
    // RigL's update is drop/grow balanced, so nnz must not drift.
    assert_eq!(on.nnz_start, on.nnz_end, "per-layer nnz drifted across mask updates");

    let (_, _, off) = train_bits(false, 1, false);
    assert_eq!(off.updates, 0);
    assert_eq!(off.train_step_s, 0.0);
    assert_eq!(off.dense_grad_s, 0.0);
    assert_eq!(off.mask_update_s, 0.0);
    assert!(off.nnz_start.is_empty() && off.nnz_end.is_empty());
}

// ---------------------------------------------------------------------------
// Allocation: warm record paths must be allocation-free.
// ---------------------------------------------------------------------------

#[test]
fn steady_state_recording_allocates_nothing() {
    let _g = serialize();
    let _flags = FlagGuard::set(true, true);
    // Cold path: registration and this thread's span ring allocate
    // exactly once, before the measured window.
    let c = metrics::counter("test.obsdet.counter");
    let h = metrics::histogram("test.obsdet.hist");
    let gauge = metrics::gauge("test.obsdet.gauge");
    {
        let _warm = trace::span("test.obsdet.warm", "test");
    }
    c.inc();
    h.record(1);
    gauge.set(1);

    let before = alloc_events();
    for i in 0..10_000u64 {
        c.add(1);
        h.record(i);
        gauge.set(i);
        let _span = trace::span_id("test.obsdet.span", "test", i);
    }
    let after = alloc_events();
    assert_eq!(
        after - before,
        0,
        "hot record path allocated {} times in 10k iterations",
        after - before
    );
    assert!(c.get() >= 10_001);
}

// ---------------------------------------------------------------------------
// Disable semantics: `--no-obs` turns every record into a no-op.
// ---------------------------------------------------------------------------

#[test]
fn disabled_flag_suppresses_all_recording() {
    let _g = serialize();
    let c = metrics::counter("test.obsdet.disabled_counter");
    let h = metrics::histogram("test.obsdet.disabled_hist");
    let gauge = metrics::gauge("test.obsdet.disabled_gauge");
    {
        let _flags = FlagGuard::set(false, false);
        c.add(100);
        c.inc();
        h.record(42);
        gauge.set(7);
        assert_eq!(c.get(), 0, "counter recorded while disabled");
        assert_eq!(h.snapshot().count(), 0, "histogram recorded while disabled");
        assert_eq!(gauge.get(), 0, "gauge recorded while disabled");
    }
    // Flag restored: the same handles record again.
    let _flags = FlagGuard::set(true, false);
    c.add(3);
    h.record(42);
    gauge.set(7);
    assert_eq!(c.get(), 3);
    assert_eq!(h.snapshot().count(), 1);
    assert_eq!(gauge.get(), 7);
}

// ---------------------------------------------------------------------------
// Histogram algebra: merge + percentile against an exact oracle.
// ---------------------------------------------------------------------------

/// Inclusive upper bound of the log2 bucket holding `v` — the
/// documented percentile representative, restated independently here.
fn oracle_ceil(v: u64) -> u64 {
    if v < 2 {
        1
    } else {
        let b = 63 - v.leading_zeros() as usize;
        if b >= 63 {
            u64::MAX
        } else {
            (1u64 << (b + 1)) - 1
        }
    }
}

#[test]
fn merged_snapshot_percentiles_match_exact_oracle() {
    let _g = serialize();
    let _flags = FlagGuard::set(true, false);
    // Two histograms fed disjoint halves of one seeded value stream
    // must merge into exactly the distribution of the whole stream.
    let mut rng = Rng::new(0xD15EA5E);
    let values: Vec<u64> = (0..1000).map(|_| rng.next_u64() % 2_000_000).collect();
    let a = metrics::Histogram::new();
    let b = metrics::Histogram::new();
    let whole = metrics::Histogram::new();
    for (i, &v) in values.iter().enumerate() {
        let half = if i % 2 == 0 { &a } else { &b };
        half.record(v);
        whole.record(v);
    }
    let mut merged = a.snapshot();
    merged.merge(&b.snapshot());
    assert_eq!(merged, whole.snapshot());
    assert_eq!(merged.count(), 1000);

    let mut sorted = values.clone();
    sorted.sort_unstable();
    for &q in &[0.5, 0.9, 0.99] {
        let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
        let expect = oracle_ceil(sorted[rank - 1]);
        assert_eq!(merged.percentile(q), expect, "q={q}");
    }
}

// ---------------------------------------------------------------------------
// Serving: a live INFO roundtrip carries the latency histograms.
// ---------------------------------------------------------------------------

#[test]
fn info_roundtrip_populates_latency_histograms() {
    let _g = serialize();
    let _flags = FlagGuard::set(true, false);
    let def = mlp_def("obs_det_serve", 784, &[32], 10, 1);
    let model = SparseModel::init_random(&def, 0.9, &Distribution::Uniform, 7).unwrap();
    let server = Server::start(model, None, ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // Before traffic: the OBS block decodes, histograms are empty.
    let idle = client.info().unwrap();
    assert_eq!(idle.stats.e2e_us.count, 0);

    let mut rng = Rng::new(3);
    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
    for _ in 0..8 {
        client.infer(&x, 3).unwrap();
    }
    let info = client.info().unwrap();
    assert_eq!(info.in_dim, 784);
    assert!(
        info.stats.e2e_us.count >= 8,
        "e2e histogram missing requests: {:?}",
        info.stats.e2e_us
    );
    assert!(
        info.stats.queue_wait_us.count >= 8,
        "queue-wait histogram missing requests: {:?}",
        info.stats.queue_wait_us
    );
    // Percentiles are bucket upper bounds: p50 ≤ p90 ≤ p99 always.
    let e = info.stats.e2e_us;
    assert!(e.p50 <= e.p90 && e.p90 <= e.p99, "non-monotone percentiles: {e:?}");
    // One serial client → executed batches of exactly 1.
    assert!(info.stats.batch_max >= 1);
    assert_eq!(info.stats.batch_p50, 1);

    server.shutdown();
}
