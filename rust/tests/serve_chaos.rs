//! Hostile-traffic suite for the serve stack: malformed frames,
//! slowloris peers, admission-control sheds, hot-reload failures, and
//! the seeded chaos-proxy soak.
//!
//! The contract under test (ISSUE 6): the server never panics or leaks
//! a hung connection; every well-formed request ends in a correct
//! reply or a typed error frame; overload produces BUSY sheds visible
//! in INFO while accepted-request latency stays bounded; and every OK
//! reply — even one that crossed a chaotic network — is bit-identical
//! to the direct `InferEngine` call (the PR 4/5 determinism contract).
//!
//! The sharded event-loop front end re-runs the matrix: the same
//! contract holds at shards ≥ 2 (soak with multi-row frames mixed in,
//! slowloris caught by the poll deadline sweep, per-shard overload
//! sheds itemized in the INFO SHARD block).
//!
//! Everything is hermetic (in-code models, ephemeral loopback ports)
//! and runs identically with and without the `pjrt` feature. The
//! fault-injection soak additionally requires `--features fault-inject`
//! (`ci.sh --chaos-smoke` runs it).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use rigl::backend::native::mlp_def;
use rigl::serve::{
    protocol as proto, top_k, BusyError, ChaosConfig, ChaosProxy, Client, InferEngine,
    RetryPolicy, ServeConfig, Server, SparseModel, TopKScratch, TransportError,
};
use rigl::sparsity::Distribution;
use rigl::util::Rng;

const IN_DIM: usize = 24;
const CLASSES: usize = 5;

fn tiny(seed: u64, sparsity: f64) -> SparseModel {
    let def = mlp_def("t", IN_DIM, &[16], CLASSES, 1);
    SparseModel::init_random(&def, sparsity, &Distribution::Uniform, seed).unwrap()
}

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rigl_chaos_it_{}_{name}", std::process::id()))
}

/// `(class, logit)` reference reply for one input, straight from the
/// engine — what every OK reply must match bit for bit.
fn reference(model: &SparseModel, x: &[f32], k: usize) -> Vec<(u32, f32)> {
    let mut eng = InferEngine::new(model, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    top_k(eng.forward(model, x, 1), k, &mut scratch, &mut want);
    want
}

fn assert_bit_identical(got: &[(u32, f32)], want: &[(u32, f32)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}");
    for ((gc, gl), (wc, wl)) in got.iter().zip(want) {
        assert_eq!(gc, wc, "{ctx}");
        assert_eq!(gl.to_bits(), wl.to_bits(), "{ctx}: class {gc} logit differs");
    }
}

/// An absurd length prefix sent over a real socket is refused without
/// ballooning server memory: the connection errors out (closed), and
/// the server keeps serving other clients.
#[test]
fn absurd_length_prefix_is_rejected_and_server_survives() {
    let model = tiny(1, 0.5);
    let server = Server::start(model.clone(), None, ServeConfig::default()).unwrap();
    let mut evil = TcpStream::connect(server.addr()).unwrap();
    evil.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    // Claim a 3.9 GB frame — far past MAX_FRAME.
    evil.write_all(&0xEAD0_BEEFu32.to_le_bytes()).unwrap();
    // The server must close on us rather than try to read/alloc it.
    let mut scratch = [0u8; 16];
    let n = evil.read(&mut scratch).unwrap_or(0);
    assert_eq!(n, 0, "server kept the connection after a hostile length prefix");
    // And an honest client is still served, bit-identically.
    let mut client = Client::connect(server.addr()).unwrap();
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
    let got = client.infer(&x, CLASSES).unwrap();
    assert_bit_identical(&got, &reference(&model, &x, CLASSES), "post-hostile-prefix");
    server.shutdown();
}

/// Garbage opcodes get a typed ERROR frame and the connection stays
/// usable; a truncated frame followed by a disconnect harms nothing.
#[test]
fn garbage_and_truncated_frames_yield_typed_errors_or_clean_close() {
    let model = tiny(3, 0.5);
    let server = Server::start(model.clone(), None, ServeConfig::default()).unwrap();

    // Garbage opcode inside a well-formed frame → ERROR frame, then
    // the same connection still answers a real request.
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    proto::write_frame(&mut stream, &[0x7f, 1, 2, 3]).unwrap();
    let mut buf = Vec::new();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    assert!(proto::read_frame(&mut reader, &mut buf).unwrap());
    match proto::decode_topk_response(&buf).unwrap() {
        proto::Response::Error(msg) => assert!(msg.contains("opcode"), "{msg}"),
        other => panic!("expected a typed error, got {other:?}"),
    }
    proto::write_frame(&mut stream, &[proto::OP_INFO]).unwrap();
    assert!(proto::read_frame(&mut reader, &mut buf).unwrap());
    assert!(matches!(
        proto::decode_info_response(&buf).unwrap(),
        proto::Response::Info { .. }
    ));

    // Truncated frame + mid-frame disconnect: just drop the socket.
    let mut torn = TcpStream::connect(server.addr()).unwrap();
    torn.write_all(&100u32.to_le_bytes()).unwrap();
    torn.write_all(&[1, 2, 3]).unwrap(); // 3 of the promised 100 bytes
    drop(torn);

    // The server is still fully functional.
    let mut client = Client::connect(server.addr()).unwrap();
    let mut rng = Rng::new(4);
    let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
    let got = client.infer(&x, 2).unwrap();
    assert_bit_identical(&got, &reference(&model, &x, 2), "post-torn-frame");
    server.shutdown();
}

/// A slowloris peer — trickling a frame slower than the per-frame
/// budget — is disconnected within the deadline while a healthy
/// connection on the same server keeps getting exact replies.
#[test]
fn slowloris_is_disconnected_while_others_are_served() {
    let model = tiny(5, 0.5);
    let server = Server::start(
        model.clone(),
        None,
        ServeConfig {
            idle_timeout_ms: 300,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr();

    let slow = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let t0 = Instant::now();
        // Claim a 64-byte frame, then dribble one byte per 100 ms: the
        // whole frame cannot land within the 300 ms frame budget.
        let mut wire = Vec::new();
        wire.extend_from_slice(&64u32.to_le_bytes());
        wire.extend_from_slice(&[0u8; 64]);
        let mut cut = None;
        for b in &wire {
            if s.write_all(std::slice::from_ref(b)).is_err() {
                cut = Some(t0.elapsed());
                break;
            }
            std::thread::sleep(Duration::from_millis(100));
            // A close is often only visible on read: poll for EOF.
            let mut probe = [0u8; 1];
            s.set_read_timeout(Some(Duration::from_millis(1))).ok();
            if let Ok(0) = s.read(&mut probe) {
                cut = Some(t0.elapsed());
                break;
            }
        }
        cut
    });

    // Healthy traffic flows the whole time.
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(6);
    for _ in 0..10 {
        let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
        let got = client.infer(&x, CLASSES).unwrap();
        assert_bit_identical(&got, &reference(&model, &x, CLASSES), "during-slowloris");
        std::thread::sleep(Duration::from_millis(30));
    }

    let cut = slow.join().unwrap();
    let cut = cut.expect("slowloris peer was never disconnected");
    assert!(
        cut < Duration::from_secs(10),
        "slowloris lingered {cut:?} before disconnect"
    );
    server.shutdown();
}

/// The admission gate: with `max_conns = 1` and one connection
/// admitted, the next peer gets exactly one typed BUSY frame and is
/// closed — deterministically, no load race required.
#[test]
fn connection_gate_sheds_typed_busy_frame() {
    let model = tiny(7, 0.5);
    let server = Server::start(
        model,
        None,
        ServeConfig {
            max_conns: 1,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    // Admit one connection and prove it's live (the accept loop has
    // counted it) before the second peer dials in.
    let mut admitted = Client::connect(server.addr()).unwrap();
    let info = admitted.info().unwrap();
    assert_eq!(info.stats.active_conns, 1);

    let refused = TcpStream::connect(server.addr()).unwrap();
    refused
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut reader = std::io::BufReader::new(refused);
    let mut buf = Vec::new();
    assert!(proto::read_frame(&mut reader, &mut buf).unwrap());
    match proto::decode_topk_response(&buf).unwrap() {
        proto::Response::Busy(msg) => assert!(msg.contains("busy"), "{msg}"),
        other => panic!("expected BUSY at the admission gate, got {other:?}"),
    }
    // ...and nothing after it: the refused socket reads clean EOF.
    assert!(!proto::read_frame(&mut reader, &mut buf).unwrap());

    // The admitted connection never noticed; the shed is in INFO.
    let info = admitted.info().unwrap();
    assert!(info.stats.shed >= 1, "shed={}", info.stats.shed);
    server.shutdown();
}

/// Queue overload: 32 connections fire simultaneously (barrier-
/// released rounds) at a 1-deep queue — most submissions in each burst
/// must shed typed BUSY, every accepted request is answered
/// bit-identically within bounded latency, and the queue gauges are
/// visible over the wire.
#[test]
fn queue_overload_sheds_busy_and_accepted_latency_stays_bounded() {
    const CONNS: usize = 32;
    const ROUNDS: usize = 6;
    let model = tiny(8, 0.5);
    let server = Server::start(
        model.clone(),
        None,
        ServeConfig {
            workers: 1,
            max_batch: 4,
            max_wait_us: 0, // no coalescing window: the queue is the only buffer
            queue_depth: 1,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    let model = &model;
    let barrier = std::sync::Barrier::new(CONNS);
    let barrier = &barrier;
    let (ok_n, busy_n) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CONNS)
            .map(|t| {
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
                    let mut rng = Rng::new(0xF100D ^ t as u64);
                    let (mut ok, mut busy) = (0usize, 0usize);
                    for _ in 0..ROUNDS {
                        let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
                        // Release all 32 submissions in the same instant:
                        // with a 1-deep queue the worker cannot drain a
                        // simultaneous burst, so sheds are forced, not a
                        // scheduling accident.
                        barrier.wait();
                        let t0 = Instant::now();
                        match client.infer(&x, CLASSES) {
                            Ok(got) => {
                                // Accepted ⇒ answered exactly, and within a
                                // bound set by queue(1) + batch size, not
                                // by the flood's total backlog.
                                assert_bit_identical(
                                    &got,
                                    &reference(model, &x, CLASSES),
                                    "overload reply",
                                );
                                assert!(
                                    t0.elapsed() < Duration::from_secs(10),
                                    "accepted request took {:?}",
                                    t0.elapsed()
                                );
                                ok += 1;
                            }
                            Err(e) if e.downcast_ref::<BusyError>().is_some() => busy += 1,
                            Err(e) => panic!("unexpected failure under overload: {e:#}"),
                        }
                    }
                    (ok, busy)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |(a, b), (o, s)| (a + o, b + s))
    });
    assert!(ok_n > 0, "overload shed every single request");
    assert!(
        busy_n > 0,
        "32 simultaneous submissions per round into a 1-deep queue never shed"
    );
    let mut probe = Client::connect(addr).unwrap();
    let info = probe.info().unwrap();
    assert_eq!(info.stats.queue_cap, 1);
    assert!(info.stats.shed >= busy_n as u64);
    server.shutdown();
}

/// Client deadlines ride the wire: a generous deadline still gets a
/// normal exact reply (the deadline-threading path is exercised end to
/// end; expiry itself is unit-tested in the batcher).
#[test]
fn wire_deadline_roundtrips() {
    let model = tiny(9, 0.5);
    let server = Server::start(model.clone(), None, ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let mut rng = Rng::new(10);
    let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
    let got = client.infer_deadline(&x, CLASSES, 5_000).unwrap();
    assert_bit_identical(&got, &reference(&model, &x, CLASSES), "deadline reply");
    server.shutdown();
}

/// Hot-reload hardening: a corrupt artifact overwrite is rejected, the
/// failure is counted into INFO, the old model keeps answering
/// bit-identically, and a subsequent good export still lands.
#[test]
fn corrupt_reload_is_counted_and_old_model_keeps_serving() {
    let a = tiny(11, 0.6);
    let b = tiny(12, 0.3);
    assert_ne!(a.nnz(), b.nnz());
    let path = temp("corrupt_reload.srvd");
    a.save(&path).unwrap();
    let server = Server::start_watching(
        path.clone(),
        ServeConfig {
            reload_poll_ms: 25,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    assert_eq!(client.info().unwrap().nnz as usize, a.nnz());

    // Corrupt overwrite (same size tricks nothing: stamp changes).
    std::fs::write(&path, b"RIGLSRVD but then it all goes wrong").unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let info = client.info().unwrap();
        if info.stats.reload_failures >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "reload failure never surfaced in INFO"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    // Old model still serving, exactly.
    let mut rng = Rng::new(13);
    let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
    let got = client.infer(&x, CLASSES).unwrap();
    assert_bit_identical(&got, &reference(&a, &x, CLASSES), "after corrupt reload");

    // A good export still swaps in.
    b.save(&path).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while client.info().unwrap().nnz as usize != b.nnz() {
        assert!(Instant::now() < deadline, "good reload never landed");
        std::thread::sleep(Duration::from_millis(20));
    }
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

/// Deleting the artifact must not kill serving (the watcher backs off
/// its polling); restoring the file resumes hot reload.
#[test]
fn missing_artifact_backs_off_and_recovers() {
    let a = tiny(14, 0.6);
    let b = tiny(15, 0.3);
    let path = temp("missing_artifact.srvd");
    a.save(&path).unwrap();
    let server = Server::start_watching(
        path.clone(),
        ServeConfig {
            reload_poll_ms: 25,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    std::fs::remove_file(&path).unwrap();
    std::thread::sleep(Duration::from_millis(200)); // let the watcher notice the hole
    assert_eq!(client.info().unwrap().nnz as usize, a.nnz());
    b.save(&path).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while client.info().unwrap().nnz as usize != b.nnz() {
        assert!(Instant::now() < deadline, "reload after restore never landed");
        std::thread::sleep(Duration::from_millis(20));
    }
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

/// The seeded chaos soak (≥4 distinct seeds): all traffic crosses the
/// chaos proxy (delays, fragmentation, dropped connections), clients
/// retry with seeded jittered backoff, and the acceptance contract
/// holds — every outcome is a bit-identical OK reply, a typed BUSY, or
/// a transport error; the server stays healthy; drain succeeds.
#[test]
fn chaos_proxy_soak_keeps_every_reply_exact_or_typed() {
    for seed in [0xC1u64, 0xC2, 0xC3, 0xC4] {
        let model = tiny(16, 0.5);
        let server = Server::start(
            model.clone(),
            None,
            ServeConfig {
                workers: 2,
                max_batch: 8,
                idle_timeout_ms: 2_000,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let proxy = ChaosProxy::start(
            server.addr(),
            ChaosConfig {
                seed,
                delay_prob: 0.15,
                max_delay_ms: 15,
                fragment_prob: 0.15,
                drop_prob: 0.03,
            },
        )
        .unwrap();
        let paddr = proxy.addr();
        let model_ref = &model;
        let (ok_n, busy_n, transport_n) = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..3)
                .map(|t| {
                    scope.spawn(move || {
                        let mut client = Client::connect(paddr).unwrap();
                        client.set_timeout(Some(Duration::from_secs(5))).unwrap();
                        let policy = RetryPolicy {
                            attempts: 5,
                            base: Duration::from_millis(2),
                            max: Duration::from_millis(50),
                            seed: seed ^ ((t as u64) << 8),
                        };
                        let mut rng = Rng::new(seed ^ 0x50AC ^ t as u64);
                        let (mut ok, mut busy, mut transport) = (0usize, 0usize, 0usize);
                        for r in 0..25 {
                            let x: Vec<f32> =
                                (0..IN_DIM).map(|_| rng.next_f32() - 0.5).collect();
                            match client.infer_retry(&x, CLASSES, 2_000, &policy) {
                                Ok(got) => {
                                    assert_bit_identical(
                                        &got,
                                        &reference(model_ref, &x, CLASSES),
                                        &format!("chaos seed={seed:#x} t={t} r={r}"),
                                    );
                                    ok += 1;
                                }
                                Err(e) if e.downcast_ref::<BusyError>().is_some() => busy += 1,
                                Err(e)
                                    if e.downcast_ref::<TransportError>().is_some() =>
                                {
                                    transport += 1;
                                    // The stream may be dead; next loop
                                    // iteration reconnects through retry.
                                    let _ = client.reconnect();
                                }
                                Err(e) => panic!(
                                    "chaos seed={seed:#x}: untyped failure for a \
                                     well-formed request: {e:#}"
                                ),
                            }
                        }
                        (ok, busy, transport)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).fold(
                (0, 0, 0),
                |(a, b, c), (o, s, t)| (a + o, b + s, c + t),
            )
        });
        // Chaos must not be able to take the success rate to zero, and
        // every single non-OK outcome was typed.
        assert!(
            ok_n > 0,
            "chaos seed={seed:#x}: no request ever succeeded (ok={ok_n} busy={busy_n} transport={transport_n})"
        );
        proxy.shutdown();

        // The server behind the proxy is untouched by the chaos:
        // direct traffic is exact, and drain completes in bound.
        let mut direct = Client::connect(server.addr()).unwrap();
        let mut rng = Rng::new(seed ^ 0xD1);
        let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
        let got = direct.infer(&x, CLASSES).unwrap();
        assert_bit_identical(&got, &reference(&model, &x, CLASSES), "post-chaos direct");
        drop(direct);
        assert!(server.drain(), "drain failed after chaos soak seed={seed:#x}");
    }
}

/// The chaos soak re-run against the SHARDED event-loop front end,
/// with multi-row INFERM frames mixed into the traffic: at shards=4
/// every outcome is still a bit-identical OK reply (single- or
/// multi-row), a typed BUSY, or a transport error, and drain walks all
/// shards. A multi-row frame retries as one idempotent unit.
#[test]
fn sharded_chaos_soak_keeps_every_reply_exact_or_typed() {
    for seed in [0x5C1u64, 0x5C2] {
        let model = tiny(26, 0.5);
        let server = Server::start(
            model.clone(),
            None,
            ServeConfig {
                shards: 4,
                workers: 2,
                max_batch: 8,
                idle_timeout_ms: 2_000,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let proxy = ChaosProxy::start(
            server.addr(),
            ChaosConfig {
                seed,
                delay_prob: 0.15,
                max_delay_ms: 15,
                fragment_prob: 0.15,
                drop_prob: 0.03,
            },
        )
        .unwrap();
        let paddr = proxy.addr();
        let model_ref = &model;
        let ok_n = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..3)
                .map(|t| {
                    scope.spawn(move || {
                        let mut client = Client::connect(paddr).unwrap();
                        client.set_timeout(Some(Duration::from_secs(5))).unwrap();
                        let policy = RetryPolicy {
                            attempts: 5,
                            base: Duration::from_millis(2),
                            max: Duration::from_millis(50),
                            seed: seed ^ ((t as u64) << 8),
                        };
                        let mut rng = Rng::new(seed ^ 0x5A4D ^ t as u64);
                        let mut ok = 0usize;
                        for r in 0..20 {
                            // Every third request is a 2-row frame.
                            let rows = if r % 3 == 0 { 2usize } else { 1 };
                            let x: Vec<f32> =
                                (0..rows * IN_DIM).map(|_| rng.next_f32() - 0.5).collect();
                            let ctx = format!("sharded chaos seed={seed:#x} t={t} r={r}");
                            let reply = if rows > 1 {
                                client.infer_batch_retry(&x, rows, CLASSES, 2_000, &policy)
                            } else {
                                client
                                    .infer_retry(&x, CLASSES, 2_000, &policy)
                                    .map(|one| vec![one])
                            };
                            match reply {
                                Ok(per_row) => {
                                    assert_eq!(per_row.len(), rows, "{ctx}");
                                    for (i, got) in per_row.iter().enumerate() {
                                        let row = &x[i * IN_DIM..(i + 1) * IN_DIM];
                                        assert_bit_identical(
                                            got,
                                            &reference(model_ref, row, CLASSES),
                                            &ctx,
                                        );
                                    }
                                    ok += 1;
                                }
                                Err(e) if e.downcast_ref::<BusyError>().is_some() => {}
                                Err(e)
                                    if e.downcast_ref::<TransportError>().is_some() =>
                                {
                                    let _ = client.reconnect();
                                }
                                Err(e) => panic!("{ctx}: untyped failure: {e:#}"),
                            }
                        }
                        ok
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
        });
        assert!(ok_n > 0, "sharded chaos seed={seed:#x}: no request ever succeeded");
        proxy.shutdown();
        let mut direct = Client::connect(server.addr()).unwrap();
        let info = direct.info().unwrap();
        assert_eq!(info.stats.shard_count, 4, "SHARD block lost under chaos");
        drop(direct);
        assert!(server.drain(), "sharded drain failed after chaos seed={seed:#x}");
    }
}

/// Slowloris against the sharded server: the poll-driven frame budget
/// (armed once at the first byte, never refreshed by trickled bytes)
/// disconnects the dribbler on whichever shard admitted it, while
/// healthy connections on other shards keep exact replies flowing.
#[test]
fn sharded_slowloris_caught_by_poll_deadline() {
    let model = tiny(27, 0.5);
    let server = Server::start(
        model.clone(),
        None,
        ServeConfig {
            shards: 4,
            idle_timeout_ms: 300,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    let slow = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let t0 = Instant::now();
        let mut wire = Vec::new();
        wire.extend_from_slice(&64u32.to_le_bytes());
        wire.extend_from_slice(&[0u8; 64]);
        let mut cut = None;
        for b in &wire {
            if s.write_all(std::slice::from_ref(b)).is_err() {
                cut = Some(t0.elapsed());
                break;
            }
            std::thread::sleep(Duration::from_millis(100));
            let mut probe = [0u8; 1];
            s.set_read_timeout(Some(Duration::from_millis(1))).ok();
            if let Ok(0) = s.read(&mut probe) {
                cut = Some(t0.elapsed());
                break;
            }
        }
        cut
    });
    let mut client = Client::connect(addr).unwrap();
    let mut rng = Rng::new(28);
    for _ in 0..10 {
        let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
        let got = client.infer(&x, CLASSES).unwrap();
        assert_bit_identical(&got, &reference(&model, &x, CLASSES), "during sharded slowloris");
        std::thread::sleep(Duration::from_millis(30));
    }
    let cut = slow.join().unwrap().expect("sharded slowloris peer was never disconnected");
    assert!(cut < Duration::from_secs(10), "slowloris lingered {cut:?}");
    server.shutdown();
}

/// Queue overload at shards=2: per-shard 1-deep queues force typed BUSY
/// sheds under a barrier-released burst, accepted requests stay exact,
/// and the per-shard SHARD block is visible and consistent with the
/// aggregate over the wire.
#[test]
fn sharded_overload_sheds_and_shard_block_is_consistent() {
    const CONNS: usize = 32;
    const ROUNDS: usize = 4;
    let model = tiny(29, 0.5);
    let server = Server::start(
        model.clone(),
        None,
        ServeConfig {
            shards: 2,
            workers: 1,
            max_batch: 4,
            max_wait_us: 0,
            queue_depth: 1, // per shard
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    let model = &model;
    let barrier = std::sync::Barrier::new(CONNS);
    let barrier = &barrier;
    let (ok_n, busy_n) = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CONNS)
            .map(|t| {
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    client.set_timeout(Some(Duration::from_secs(30))).unwrap();
                    let mut rng = Rng::new(0x5F1D ^ t as u64);
                    let (mut ok, mut busy) = (0usize, 0usize);
                    for _ in 0..ROUNDS {
                        let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
                        barrier.wait();
                        match client.infer(&x, CLASSES) {
                            Ok(got) => {
                                assert_bit_identical(
                                    &got,
                                    &reference(model, &x, CLASSES),
                                    "sharded overload reply",
                                );
                                ok += 1;
                            }
                            Err(e) if e.downcast_ref::<BusyError>().is_some() => busy += 1,
                            Err(e) => panic!("unexpected failure under sharded overload: {e:#}"),
                        }
                    }
                    (ok, busy)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |(a, b), (o, s)| (a + o, b + s))
    });
    assert!(ok_n > 0, "sharded overload shed every single request");
    assert!(busy_n > 0, "32-way bursts into per-shard 1-deep queues never shed");
    let mut probe = Client::connect(addr).unwrap();
    let info = probe.info().unwrap();
    // queue_cap aggregates per-shard caps; the SHARD block itemizes.
    assert_eq!(info.stats.queue_cap, 2);
    assert_eq!(info.stats.shard_count, 2);
    let shard_shed: u64 = info.stats.shards[..2].iter().map(|s| s.shed).sum();
    assert!(
        shard_shed <= info.stats.shed,
        "per-shard sheds {shard_shed} exceed the aggregate {}",
        info.stats.shed
    );
    assert!(info.stats.shed >= busy_n as u64);
    server.shutdown();
}

/// With `fault-inject` armed, in-process failure points fire inside
/// the server (enqueue sheds, socket read/write faults) and the same
/// outcome contract holds; the fire counters prove the faults were
/// real. Runs under `ci.sh --chaos-smoke`.
#[cfg(feature = "fault-inject")]
#[test]
fn fault_injection_soak_stays_typed_and_exact() {
    use rigl::serve::faults;
    for seed in [0xFA_17u64, 0xFA_18, 0xFA_19, 0xFA_20] {
        faults::arm(seed, 0.0);
        faults::arm_site(faults::Site::Enqueue, seed, 0.10);
        faults::arm_site(faults::Site::SockRead, seed, 0.03);
        faults::arm_site(faults::Site::SockWrite, seed, 0.03);
        let model = tiny(17, 0.5);
        let server = Server::start(model.clone(), None, ServeConfig::default()).unwrap();
        let addr = server.addr();
        let model_ref = &model;
        let ok_n = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..3)
                .map(|t| {
                    scope.spawn(move || {
                        let mut client = Client::connect(addr).unwrap();
                        client.set_timeout(Some(Duration::from_secs(5))).unwrap();
                        let policy = RetryPolicy {
                            attempts: 6,
                            base: Duration::from_millis(1),
                            max: Duration::from_millis(20),
                            seed: seed ^ t as u64,
                        };
                        let mut rng = Rng::new(seed ^ 0xFA ^ t as u64);
                        let mut ok = 0usize;
                        for r in 0..25 {
                            let x: Vec<f32> =
                                (0..IN_DIM).map(|_| rng.next_f32() - 0.5).collect();
                            match client.infer_retry(&x, CLASSES, 0, &policy) {
                                Ok(got) => {
                                    assert_bit_identical(
                                        &got,
                                        &reference(model_ref, &x, CLASSES),
                                        &format!("faults seed={seed:#x} t={t} r={r}"),
                                    );
                                    ok += 1;
                                }
                                Err(e) if e.downcast_ref::<BusyError>().is_some() => {}
                                Err(e)
                                    if e.downcast_ref::<TransportError>().is_some() =>
                                {
                                    let _ = client.reconnect();
                                }
                                Err(e) => panic!("untyped failure under faults: {e:#}"),
                            }
                        }
                        ok
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
        });
        let fired: u64 = faults::counts().iter().sum();
        faults::disarm();
        assert!(fired > 0, "seed={seed:#x}: no injected fault ever fired");
        assert!(ok_n > 0, "seed={seed:#x}: faults took success to zero");
        // Disarmed, the server serves exactly as before.
        let mut client = Client::connect(server.addr()).unwrap();
        let mut rng = Rng::new(seed ^ 0xFE);
        let x: Vec<f32> = (0..IN_DIM).map(|_| rng.next_f32()).collect();
        let got = client.infer(&x, CLASSES).unwrap();
        assert_bit_identical(&got, &reference(&model, &x, CLASSES), "post-faults");
        drop(client);
        server.shutdown();
    }
}

/// Armed artifact-load faults make hot reloads fail deterministically;
/// the old model keeps serving and the failures are counted — the same
/// path a genuinely corrupt artifact takes.
#[cfg(feature = "fault-inject")]
#[test]
fn artifact_load_fault_keeps_old_model() {
    use rigl::serve::faults;
    let a = tiny(18, 0.6);
    let b = tiny(19, 0.3);
    let path = temp("fault_reload.srvd");
    a.save(&path).unwrap();
    // Arm AFTER the initial load (rate 1.0: every reload dies).
    let server = Server::start_watching(
        path.clone(),
        ServeConfig {
            reload_poll_ms: 25,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    faults::arm(0xAF, 0.0);
    faults::arm_site(faults::Site::ArtifactLoad, 0xAF, 1.0);
    b.save(&path).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while client.info().unwrap().stats.reload_failures == 0 {
        assert!(Instant::now() < deadline, "injected reload failure never counted");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(client.info().unwrap().nnz as usize, a.nnz(), "old model was replaced");
    // Disarm: the next observed change loads fine. Re-save in the wait
    // loop so the watcher is guaranteed a fresh stamp even on coarse
    // mtime filesystems (the length matches the failed artifact's).
    faults::disarm();
    let deadline = Instant::now() + Duration::from_secs(10);
    while client.info().unwrap().nnz as usize != b.nnz() {
        assert!(Instant::now() < deadline, "reload after disarm never landed");
        b.save(&path).unwrap();
        std::thread::sleep(Duration::from_millis(40));
    }
    server.shutdown();
    std::fs::remove_file(&path).ok();
}
