//! Serve-subsystem round-trip suite: frozen artifacts, the micro-
//! batcher's bit-identity contract, loopback TCP serving, and hot
//! reload. Everything here is hermetic — models are built in code via
//! `backend::native::mlp_def`, servers bind ephemeral loopback ports —
//! so the suite runs identically with and without the `pjrt` feature
//! (the `--no-pjrt` CI path).

use std::sync::Arc;
use std::time::Duration;

use rigl::backend::native::mlp_def;
use rigl::serve::{
    run_load, top_k, Batcher, BatcherConfig, Client, InferEngine, ModelHandle, ServeConfig,
    Server, SparseModel, TopKScratch,
};
use rigl::sparsity::Distribution;
use rigl::util::Rng;

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rigl_serve_it_{}_{name}", std::process::id()))
}

/// One request's `(class, logit)` reply.
type Reply = Vec<(u32, f32)>;

fn lenet(seed: u64, sparsity: f64) -> SparseModel {
    // The paper's LeNet-300-100, as the builtin manifest serves it.
    let def = mlp_def("mlp", 784, &[300, 100], 10, 1);
    SparseModel::init_random(&def, sparsity, &Distribution::Uniform, seed).unwrap()
}

/// Export→load preserves every weight bit-exactly, on the real
/// LeNet-300-100 shape, and the artifact carries no dense storage: its
/// size must scale with nnz, not with the dense parameter count.
#[test]
fn export_load_roundtrip_bit_exact_and_nnz_sized() {
    let m = lenet(1, 0.9);
    let path = temp("rt.srvd");
    m.save(&path).unwrap();
    let back = SparseModel::load(&path).unwrap();
    assert_eq!(back.name, m.name);
    assert_eq!(back.layers.len(), m.layers.len());
    for (a, b) in back.layers.iter().zip(&m.layers) {
        assert_eq!(a.topo.row_ptr, b.topo.row_ptr);
        assert_eq!(a.topo.col_idx, b.topo.col_idx);
        assert_eq!(a.values.len(), b.values.len());
        for (x, y) in a.values.iter().zip(&b.values) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.bias.iter().zip(&b.bias) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    let sparse_bytes = std::fs::metadata(&path).unwrap().len();
    let dense = lenet(1, 0.0);
    let dense_path = temp("rt_dense.srvd");
    dense.save(&dense_path).unwrap();
    let dense_bytes = std::fs::metadata(&dense_path).unwrap().len();
    // S=0.9 keeps ~10% of values+indices; the artifact must reflect
    // that (generous 4× bound to absorb the indptr/bias floor).
    assert!(
        sparse_bytes * 4 < dense_bytes,
        "S=0.9 artifact is {sparse_bytes} bytes vs dense {dense_bytes}"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&dense_path).ok();
}

/// A loopback TCP request must return logits bit-identical to a direct
/// in-process kernel call on the same frozen model.
#[test]
fn tcp_logits_bit_identical_to_direct_kernel_call() {
    let model = lenet(2, 0.95);
    let classes = model.classes();
    let server = Server::start(model.clone(), None, ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let info = client.info().unwrap();
    assert_eq!(info.in_dim, 784);
    assert_eq!(info.classes, classes);
    assert_eq!(info.nnz as usize, model.nnz());

    let mut eng = InferEngine::new(&model, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    let mut rng = Rng::new(3);
    for _ in 0..10 {
        let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
        // k = classes ⇒ the reply is the full ranked logits row.
        let got = client.infer(&x, classes).unwrap();
        let logits = eng.forward(&model, &x, 1);
        top_k(logits, classes, &mut scratch, &mut want);
        assert_eq!(got.len(), classes);
        for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
            assert_eq!(gc, wc);
            assert_eq!(gl.to_bits(), wl.to_bits(), "class {gc} logit differs");
        }
    }

    // A malformed request is answered with an error and the connection
    // stays usable.
    let err = client.infer(&[1.0, 2.0], 1).unwrap_err().to_string();
    assert!(err.contains("takes 784"), "{err}");
    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
    assert_eq!(client.infer(&x, 1).unwrap().len(), 1);

    server.shutdown();
}

/// Micro-batcher property test: ANY interleaving of concurrent
/// requests yields per-request outputs identical to batch=1 execution.
/// Many submitter threads race tiny sleeps so requests land in
/// adversarial orders and coalesce into varying batch shapes.
#[test]
fn batcher_interleavings_match_batch1_bitwise() {
    let def = mlp_def("t", 24, &[16], 5, 1);
    let model = SparseModel::init_random(&def, 0.6, &Distribution::Uniform, 4).unwrap();
    for &(workers, max_batch, wait_us) in
        &[(1usize, 1usize, 0u64), (2, 4, 150), (4, 8, 300), (3, 32, 50)]
    {
        let batcher = Arc::new(Batcher::new(
            ModelHandle::new(model.clone()),
            BatcherConfig {
                workers,
                max_batch,
                max_wait: Duration::from_micros(wait_us),
                queue_depth: 64,
            },
        ));
        let threads = 6;
        let per_thread = 12;
        let results: Vec<Vec<Reply>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let batcher = batcher.clone();
                    scope.spawn(move || {
                        let mut rng = Rng::new(0xBA7C4 ^ t as u64);
                        let mut out = Vec::with_capacity(per_thread);
                        for r in 0..per_thread {
                            let x: Vec<f32> =
                                (0..24).map(|_| rng.next_f32() - 0.5).collect();
                            if r % 3 == 0 {
                                std::thread::sleep(Duration::from_micros(
                                    (rng.next_below(200)) as u64,
                                ));
                            }
                            let k = 1 + rng.next_below(5);
                            out.push(batcher.submit(x, k).recv().unwrap().unwrap());
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        // Recompute every request serially at batch=1 with the same
        // deterministic input streams.
        let mut eng = InferEngine::new(&model, 1);
        let mut scratch = TopKScratch::default();
        let mut want = Vec::new();
        for (t, got_thread) in results.iter().enumerate() {
            let mut rng = Rng::new(0xBA7C4 ^ t as u64);
            for (r, got) in got_thread.iter().enumerate() {
                let x: Vec<f32> = (0..24).map(|_| rng.next_f32() - 0.5).collect();
                if r % 3 == 0 {
                    let _ = rng.next_below(200); // keep the stream aligned
                }
                let k = 1 + rng.next_below(5);
                let logits = eng.forward(&model, &x, 1);
                top_k(logits, k, &mut scratch, &mut want);
                assert_eq!(got.len(), want.len(), "w={workers} b={max_batch}");
                for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
                    assert_eq!(gc, wc, "w={workers} b={max_batch} t={t} r={r}");
                    assert_eq!(gl.to_bits(), wl.to_bits());
                }
            }
        }
        let (reqs, batches) = batcher.stats();
        assert_eq!(reqs as usize, threads * per_thread);
        assert!(batches >= 1);
    }
}

/// Fan many concurrent TCP connections at one server: every reply must
/// still be bit-identical to batch=1, end to end through the protocol.
#[test]
fn concurrent_tcp_connections_all_get_exact_replies() {
    let model = lenet(5, 0.98);
    let server = Server::start(
        model.clone(),
        None,
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait_us: 200,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    let conns = 8;
    let per_conn = 10;
    let model = &model;
    std::thread::scope(|scope| {
        for c in 0..conns {
            scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut eng = InferEngine::new(model, 1);
                let mut scratch = TopKScratch::default();
                let mut want = Vec::new();
                let mut rng = Rng::new(0x7C9 ^ c as u64);
                for _ in 0..per_conn {
                    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
                    let got = client.infer(&x, 3).unwrap();
                    let logits = eng.forward(model, &x, 1);
                    top_k(logits, 3, &mut scratch, &mut want);
                    for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
                        assert_eq!(gc, wc);
                        assert_eq!(gl.to_bits(), wl.to_bits());
                    }
                }
            });
        }
    });
    let (reqs, _) = server.stats();
    assert_eq!(reqs as usize, conns * per_conn);
    server.shutdown();
}

/// Hot reload: overwrite the watched artifact (atomic rename, as
/// `repro export` does) and poll until the server answers from the new
/// weights.
#[test]
fn hot_reload_swaps_model_without_restart() {
    let a = lenet(6, 0.9);
    let b = lenet(7, 0.5); // different structure AND values
    assert_ne!(a.nnz(), b.nnz());
    let path = temp("reload.srvd");
    a.save(&path).unwrap();
    // start_watching stamps before loading — the race-free path
    // `repro serve` uses.
    let server = Server::start_watching(
        path.clone(),
        ServeConfig {
            reload_poll_ms: 25,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    assert_eq!(client.info().unwrap().nnz as usize, a.nnz());

    // Export the replacement over the same path (tmp + rename): the
    // watcher must pick it up without a restart.
    b.save(&path).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let nnz = client.info().unwrap().nnz as usize;
        if nnz == b.nnz() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "reload not observed within 10s (still {nnz} nnz)"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    // And inference now matches the new model bit-exactly.
    let mut rng = Rng::new(8);
    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
    let got = client.infer(&x, 10).unwrap();
    let mut eng = InferEngine::new(&b, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    top_k(eng.forward(&b, &x, 1), 10, &mut scratch, &mut want);
    for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
        assert_eq!(gc, wc);
        assert_eq!(gl.to_bits(), wl.to_bits());
    }
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

/// `max_requests` makes the server self-terminating — the CI smoke
/// test's clean-shutdown mechanism — and the load generator sees every
/// reply first.
#[test]
fn max_requests_terminates_cleanly_after_replies() {
    let model = lenet(9, 0.9);
    let server = Server::start(
        model,
        None,
        ServeConfig {
            max_requests: 5,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr().to_string();
    let stats = run_load(&addr, 1, 5, 1).unwrap();
    assert_eq!(stats.requests, 5);
    assert!(stats.rps > 0.0 && stats.p99_us >= stats.p50_us);
    // The accept loop stops on its own; wait() must return.
    server.wait();
}
