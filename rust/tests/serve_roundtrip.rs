//! Serve-subsystem round-trip suite: frozen artifacts, the micro-
//! batcher's bit-identity contract, loopback TCP serving, and hot
//! reload. Everything here is hermetic — models are built in code via
//! `backend::native::mlp_def`, servers bind ephemeral loopback ports —
//! so the suite runs identically with and without the `pjrt` feature
//! (the `--no-pjrt` CI path).

use std::sync::Arc;
use std::time::Duration;

use rigl::backend::native::mlp_def;
use rigl::serve::{
    run_load, top_k, Batcher, BatcherConfig, Client, InferEngine, ModelHandle, ServeConfig,
    Server, SparseModel, TopKScratch, ValueKind,
};
use rigl::sparsity::Distribution;
use rigl::util::Rng;

fn temp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("rigl_serve_it_{}_{name}", std::process::id()))
}

/// One request's `(class, logit)` reply.
type Reply = Vec<(u32, f32)>;

fn lenet(seed: u64, sparsity: f64) -> SparseModel {
    // The paper's LeNet-300-100, as the builtin manifest serves it.
    let def = mlp_def("mlp", 784, &[300, 100], 10, 1);
    SparseModel::init_random(&def, sparsity, &Distribution::Uniform, seed).unwrap()
}

/// Export→load preserves every weight bit-exactly, on the real
/// LeNet-300-100 shape, and the artifact carries no dense storage: its
/// size must scale with nnz, not with the dense parameter count.
#[test]
fn export_load_roundtrip_bit_exact_and_nnz_sized() {
    let m = lenet(1, 0.9);
    let path = temp("rt.srvd");
    m.save(&path).unwrap();
    let back = SparseModel::load(&path).unwrap();
    assert_eq!(back.name, m.name);
    assert_eq!(back.layers.len(), m.layers.len());
    for (a, b) in back.layers.iter().zip(&m.layers) {
        assert_eq!(a.topo.row_ptr, b.topo.row_ptr);
        assert_eq!(a.topo.col_idx, b.topo.col_idx);
        let (av, bv) = (a.plain_values().unwrap(), b.plain_values().unwrap());
        assert_eq!(av.len(), bv.len());
        for (x, y) in av.iter().zip(bv) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.bias.iter().zip(&b.bias) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    let sparse_bytes = std::fs::metadata(&path).unwrap().len();
    let dense = lenet(1, 0.0);
    let dense_path = temp("rt_dense.srvd");
    dense.save(&dense_path).unwrap();
    let dense_bytes = std::fs::metadata(&dense_path).unwrap().len();
    // S=0.9 keeps ~10% of values+indices; the artifact must reflect
    // that (generous 4× bound to absorb the indptr/bias floor).
    assert!(
        sparse_bytes * 4 < dense_bytes,
        "S=0.9 artifact is {sparse_bytes} bytes vs dense {dense_bytes}"
    );
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&dense_path).ok();
}

/// The RIGLSRVD v2 acceptance gates on the paper's LeNet-300-100 at
/// S=0.9: the delta-compressed artifact decodes to structures bit-exact
/// against the v1 file of the same model, and the compression actually
/// pays — ≥40% smaller with f16 values (the headline acceptance
/// number), ≥25% smaller with bit-exact f32 values.
#[test]
fn v2_export_matches_v1_structures_and_is_at_least_40pct_smaller() {
    let m = lenet(10, 0.9);
    let p1 = temp("fmt_v1.srvd");
    let p2 = temp("fmt_v2f32.srvd");
    let p3 = temp("fmt_v2f16.srvd");
    m.save(&p1).unwrap();
    m.save_v2(&p2, ValueKind::F32).unwrap();
    m.save_v2(&p3, ValueKind::F16).unwrap();
    let v1m = SparseModel::load(&p1).unwrap();
    for p in [&p2, &p3] {
        let v2m = SparseModel::load(p).unwrap();
        assert!(v2m.is_packed());
        assert_eq!(v2m.nnz(), v1m.nnz());
        for (a, b) in v2m.layers.iter().zip(&v1m.layers) {
            assert_eq!(a.topo.row_ptr, b.topo.row_ptr);
            assert_eq!(a.decode_col_idx(), b.topo.col_idx);
            assert_eq!(a.topo.blocks.col_blk, b.topo.blocks.col_blk);
        }
    }
    // The f32-valued v2 file decodes values bit-identical to v1.
    let v2m = SparseModel::load(&p2).unwrap();
    for (a, b) in v2m.layers.iter().zip(&v1m.layers) {
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.decode_values()), bits(b.plain_values().unwrap()));
    }
    let len = |p: &std::path::Path| std::fs::metadata(p).unwrap().len() as f64;
    let (b1, b2, b3) = (len(&p1), len(&p2), len(&p3));
    assert!(b2 <= 0.75 * b1, "v2+f32 is {b2} bytes vs v1 {b1}");
    assert!(b3 <= 0.60 * b1, "v2+f16 is {b3} bytes vs v1 {b1} (needs ≥40% smaller)");
    for p in [&p1, &p2, &p3] {
        std::fs::remove_file(p).ok();
    }
}

/// The determinism contract across the FORMAT axis: a packed f32 model
/// loaded from a v2 artifact serves logits bit-identical to the plain
/// model — at every batch size (flat, panel, ragged-tail paths), at
/// threads {1, 2, 8}, and end to end through TCP.
#[test]
fn packed_f32_serving_bit_identical_across_threads_and_tcp() {
    let plain = lenet(11, 0.9);
    let path = temp("v2serve.srvd");
    plain.save_v2(&path, ValueKind::F32).unwrap();
    let packed = SparseModel::load(&path).unwrap();
    assert!(packed.is_packed());
    let mut rng = Rng::new(12);
    for batch in [1usize, 8, 12] {
        let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();
        let mut pe = InferEngine::new(&plain, batch);
        let want: Vec<u32> = pe
            .forward(&plain, &x, batch)
            .iter()
            .map(|v| v.to_bits())
            .collect();
        let mut se = InferEngine::new(&packed, batch);
        let got: Vec<u32> = se
            .forward(&packed, &x, batch)
            .iter()
            .map(|v| v.to_bits())
            .collect();
        assert_eq!(got, want, "serial batch={batch}");
        for threads in [2usize, 8] {
            let pool = Arc::new(rigl::pool::KernelPool::with_par_min_ops(threads, 1));
            let mut eng = InferEngine::new(&packed, batch);
            eng.set_pool(Some(pool));
            let got: Vec<u32> = eng
                .forward(&packed, &x, batch)
                .iter()
                .map(|v| v.to_bits())
                .collect();
            assert_eq!(got, want, "batch={batch} threads={threads}");
        }
    }
    // End to end: serve the packed model over loopback TCP and compare
    // against the plain model's direct forward.
    let server = Server::start(packed.clone(), None, ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let mut eng = InferEngine::new(&plain, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    for _ in 0..5 {
        let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
        let got = client.infer(&x, 10).unwrap();
        top_k(eng.forward(&plain, &x, 1), 10, &mut scratch, &mut want);
        for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
            assert_eq!(gc, wc);
            assert_eq!(gl.to_bits(), wl.to_bits());
        }
    }
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

/// The f16 acceptance gates: logits within an epsilon bound of the f32
/// reference, and top-1 agreement on every row whose f32 margin exceeds
/// twice that bound (near-ties are legitimately allowed to flip, so the
/// deterministic gate can't be flaky).
#[test]
fn f16_serving_epsilon_bounded_with_top1_agreement() {
    let plain = lenet(13, 0.9);
    let path = temp("v2f16serve.srvd");
    plain.save_v2(&path, ValueKind::F16).unwrap();
    let half = SparseModel::load(&path).unwrap();
    let mut rng = Rng::new(14);
    let batch = 16;
    let classes = plain.classes();
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();
    let mut pe = InferEngine::new(&plain, batch);
    let want = pe.forward(&plain, &x, batch).to_vec();
    let mut he = InferEngine::new(&half, batch);
    let got = he.forward(&half, &x, batch).to_vec();
    // Epsilon bound: each weight carries one RNE rounding (relative
    // error ≤ 2⁻¹¹); the forward is three accumulations of ≤784 terms,
    // so 2% of the logit scale is a comfortably safe analytic bound —
    // and everything is deterministic, so this can't flake.
    let scale = want.iter().fold(1.0f32, |m, v| m.max(v.abs()));
    let eps = 0.02 * scale;
    for (a, e) in got.iter().zip(&want) {
        assert!((a - e).abs() <= eps, "{a} vs {e} (eps {eps})");
    }
    // Top-1 agreement on confident rows: if the f32 margin between the
    // best and second-best logit exceeds 2·eps, no eps-bounded
    // perturbation can change the argmax.
    let mut confident = 0usize;
    for b in 0..batch {
        let row = &want[b * classes..(b + 1) * classes];
        let mut idx: Vec<usize> = (0..classes).collect();
        idx.sort_by(|&i, &j| row[j].partial_cmp(&row[i]).unwrap());
        let margin = row[idx[0]] - row[idx[1]];
        if margin > 2.0 * eps {
            confident += 1;
            let grow = &got[b * classes..(b + 1) * classes];
            let gmax = (0..classes).max_by(|&i, &j| grow[i].partial_cmp(&grow[j]).unwrap());
            assert_eq!(gmax.unwrap(), idx[0], "row {b} flipped top-1");
        }
    }
    assert!(confident > 0, "no confident rows — the agreement gate is vacuous");
    std::fs::remove_file(&path).ok();
}

/// A loopback TCP request must return logits bit-identical to a direct
/// in-process kernel call on the same frozen model.
#[test]
fn tcp_logits_bit_identical_to_direct_kernel_call() {
    let model = lenet(2, 0.95);
    let classes = model.classes();
    let server = Server::start(model.clone(), None, ServeConfig::default()).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    let info = client.info().unwrap();
    assert_eq!(info.in_dim, 784);
    assert_eq!(info.classes, classes);
    assert_eq!(info.nnz as usize, model.nnz());

    let mut eng = InferEngine::new(&model, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    let mut rng = Rng::new(3);
    for _ in 0..10 {
        let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
        // k = classes ⇒ the reply is the full ranked logits row.
        let got = client.infer(&x, classes).unwrap();
        let logits = eng.forward(&model, &x, 1);
        top_k(logits, classes, &mut scratch, &mut want);
        assert_eq!(got.len(), classes);
        for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
            assert_eq!(gc, wc);
            assert_eq!(gl.to_bits(), wl.to_bits(), "class {gc} logit differs");
        }
    }

    // A malformed request is answered with an error and the connection
    // stays usable.
    let err = client.infer(&[1.0, 2.0], 1).unwrap_err().to_string();
    assert!(err.contains("takes 784"), "{err}");
    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
    assert_eq!(client.infer(&x, 1).unwrap().len(), 1);

    server.shutdown();
}

/// Micro-batcher property test: ANY interleaving of concurrent
/// requests yields per-request outputs identical to batch=1 execution.
/// Many submitter threads race tiny sleeps so requests land in
/// adversarial orders and coalesce into varying batch shapes.
#[test]
fn batcher_interleavings_match_batch1_bitwise() {
    let def = mlp_def("t", 24, &[16], 5, 1);
    let model = SparseModel::init_random(&def, 0.6, &Distribution::Uniform, 4).unwrap();
    for &(workers, max_batch, wait_us) in
        &[(1usize, 1usize, 0u64), (2, 4, 150), (4, 8, 300), (3, 32, 50)]
    {
        let batcher = Arc::new(Batcher::new(
            ModelHandle::new(model.clone()),
            BatcherConfig {
                workers,
                max_batch,
                max_wait: Duration::from_micros(wait_us),
                queue_depth: 64,
            },
        ));
        let threads = 6;
        let per_thread = 12;
        let results: Vec<Vec<Reply>> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let batcher = batcher.clone();
                    scope.spawn(move || {
                        let mut rng = Rng::new(0xBA7C4 ^ t as u64);
                        let mut out = Vec::with_capacity(per_thread);
                        for r in 0..per_thread {
                            let x: Vec<f32> =
                                (0..24).map(|_| rng.next_f32() - 0.5).collect();
                            if r % 3 == 0 {
                                std::thread::sleep(Duration::from_micros(
                                    (rng.next_below(200)) as u64,
                                ));
                            }
                            let k = 1 + rng.next_below(5);
                            out.push(batcher.submit(x, k).recv().unwrap().unwrap());
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        // Recompute every request serially at batch=1 with the same
        // deterministic input streams.
        let mut eng = InferEngine::new(&model, 1);
        let mut scratch = TopKScratch::default();
        let mut want = Vec::new();
        for (t, got_thread) in results.iter().enumerate() {
            let mut rng = Rng::new(0xBA7C4 ^ t as u64);
            for (r, got) in got_thread.iter().enumerate() {
                let x: Vec<f32> = (0..24).map(|_| rng.next_f32() - 0.5).collect();
                if r % 3 == 0 {
                    let _ = rng.next_below(200); // keep the stream aligned
                }
                let k = 1 + rng.next_below(5);
                let logits = eng.forward(&model, &x, 1);
                top_k(logits, k, &mut scratch, &mut want);
                assert_eq!(got.len(), want.len(), "w={workers} b={max_batch}");
                for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
                    assert_eq!(gc, wc, "w={workers} b={max_batch} t={t} r={r}");
                    assert_eq!(gl.to_bits(), wl.to_bits());
                }
            }
        }
        let (reqs, batches) = batcher.stats();
        assert_eq!(reqs as usize, threads * per_thread);
        assert!(batches >= 1);
    }
}

/// Fan many concurrent TCP connections at one server: every reply must
/// still be bit-identical to batch=1, end to end through the protocol.
#[test]
fn concurrent_tcp_connections_all_get_exact_replies() {
    let model = lenet(5, 0.98);
    let server = Server::start(
        model.clone(),
        None,
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait_us: 200,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr();
    let conns = 8;
    let per_conn = 10;
    let model = &model;
    std::thread::scope(|scope| {
        for c in 0..conns {
            scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut eng = InferEngine::new(model, 1);
                let mut scratch = TopKScratch::default();
                let mut want = Vec::new();
                let mut rng = Rng::new(0x7C9 ^ c as u64);
                for _ in 0..per_conn {
                    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
                    let got = client.infer(&x, 3).unwrap();
                    let logits = eng.forward(model, &x, 1);
                    top_k(logits, 3, &mut scratch, &mut want);
                    for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
                        assert_eq!(gc, wc);
                        assert_eq!(gl.to_bits(), wl.to_bits());
                    }
                }
            });
        }
    });
    let (reqs, _) = server.stats();
    assert_eq!(reqs as usize, conns * per_conn);
    server.shutdown();
}

/// Hot reload: overwrite the watched artifact (atomic rename, as
/// `repro export` does) and poll until the server answers from the new
/// weights.
#[test]
fn hot_reload_swaps_model_without_restart() {
    let a = lenet(6, 0.9);
    let b = lenet(7, 0.5); // different structure AND values
    assert_ne!(a.nnz(), b.nnz());
    let path = temp("reload.srvd");
    a.save(&path).unwrap();
    // start_watching stamps before loading — the race-free path
    // `repro serve` uses.
    let server = Server::start_watching(
        path.clone(),
        ServeConfig {
            reload_poll_ms: 25,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    assert_eq!(client.info().unwrap().nnz as usize, a.nnz());

    // Export the replacement over the same path (tmp + rename): the
    // watcher must pick it up without a restart.
    b.save(&path).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        let nnz = client.info().unwrap().nnz as usize;
        if nnz == b.nnz() {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "reload not observed within 10s (still {nnz} nnz)"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    // And inference now matches the new model bit-exactly.
    let mut rng = Rng::new(8);
    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
    let got = client.infer(&x, 10).unwrap();
    let mut eng = InferEngine::new(&b, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    top_k(eng.forward(&b, &x, 1), 10, &mut scratch, &mut want);
    for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
        assert_eq!(gc, wc);
        assert_eq!(gl.to_bits(), wl.to_bits());
    }
    server.shutdown();
    std::fs::remove_file(&path).ok();
}

/// The tentpole determinism contract of the sharded front end: at ANY
/// shards × workers × threads the server's replies are bit-identical to
/// a direct engine call — sharding only changes who runs the forward,
/// never what it computes.
#[test]
fn sharded_replies_bit_identical_across_shards_workers_threads() {
    let model = lenet(20, 0.95);
    let classes = model.classes();
    let mut eng = InferEngine::new(&model, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    for &(shards, workers, threads) in &[(1usize, 1usize, 1usize), (1, 2, 2), (4, 1, 1), (4, 2, 2)] {
        let server = Server::start(
            model.clone(),
            None,
            ServeConfig {
                shards,
                workers,
                threads,
                max_batch: 8,
                max_wait_us: 100,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let addr = server.addr();
        // Several connections so shards ≥ 2 actually spread the load.
        std::thread::scope(|scope| {
            for c in 0..4usize {
                let model = &model;
                scope.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let mut eng = InferEngine::new(model, 1);
                    let mut scratch = TopKScratch::default();
                    let mut want = Vec::new();
                    let mut rng = Rng::new(0x54A2D ^ c as u64);
                    for _ in 0..6 {
                        let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
                        let got = client.infer(&x, classes).unwrap();
                        top_k(eng.forward(model, &x, 1), classes, &mut scratch, &mut want);
                        for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
                            assert_eq!(gc, wc, "shards={shards} w={workers} t={threads}");
                            assert_eq!(gl.to_bits(), wl.to_bits());
                        }
                    }
                });
            }
        });
        // The INFO SHARD block reflects the topology.
        let mut client = Client::connect(addr).unwrap();
        let info = client.info().unwrap();
        assert_eq!(info.stats.shard_count as usize, shards, "shards={shards}");
        let mut rng = Rng::new(0x1D);
        let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
        let got = client.infer(&x, classes).unwrap();
        top_k(eng.forward(&model, &x, 1), classes, &mut scratch, &mut want);
        for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
            assert_eq!(gc, wc);
            assert_eq!(gl.to_bits(), wl.to_bits());
        }
        server.shutdown();
    }
}

/// Multi-row INFERM frames: R rows in one frame come back bit-identical
/// to R single-row INFER calls (and to the direct engine), in frame
/// order, through a sharded server — client-side batching never changes
/// numerics.
#[test]
fn multi_row_frames_bit_identical_to_single_row_calls() {
    let model = lenet(21, 0.9);
    let classes = model.classes();
    let server = Server::start(
        model.clone(),
        None,
        ServeConfig {
            shards: 2,
            workers: 2,
            max_batch: 8,
            max_wait_us: 100,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let mut eng = InferEngine::new(&model, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    let mut rng = Rng::new(22);
    for &rows in &[1usize, 3, 8] {
        let x: Vec<f32> = (0..rows * 784).map(|_| rng.next_f32()).collect();
        let per_row = client.infer_batch(&x, rows, classes, 0).unwrap();
        assert_eq!(per_row.len(), rows);
        for (r, got) in per_row.iter().enumerate() {
            let row = &x[r * 784..(r + 1) * 784];
            // vs a single-row INFER on the same connection…
            let single = client.infer(row, classes).unwrap();
            assert_eq!(got, &single, "rows={rows} r={r}");
            // …and vs the direct engine call.
            top_k(eng.forward(&model, row, 1), classes, &mut scratch, &mut want);
            for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
                assert_eq!(gc, wc);
                assert_eq!(gl.to_bits(), wl.to_bits());
            }
        }
    }
    // A malformed multi-row frame gets ONE typed error for the whole
    // frame and the connection stays usable.
    let err = client.infer_batch(&vec![0.5f32; 784 * 2], 2, 1, 0);
    assert!(err.is_ok(), "well-formed 2-row frame must succeed");
    let bad = client
        .infer_batch(&vec![0.5f32; 10], 2, 1, 0)
        .unwrap_err()
        .to_string();
    assert!(bad.contains("2 rows"), "{bad}");
    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
    assert_eq!(client.infer(&x, 1).unwrap().len(), 1);
    server.shutdown();
}

/// Hot reload and graceful drain, end to end against the sharded
/// server: one atomic swap serves every shard's replicas, and drain
/// finishes in-flight work across all shards.
#[test]
fn sharded_hot_reload_and_drain_e2e() {
    let a = lenet(23, 0.9);
    let b = lenet(24, 0.5);
    assert_ne!(a.nnz(), b.nnz());
    let path = temp("reload_sharded.srvd");
    a.save(&path).unwrap();
    let server = Server::start_watching(
        path.clone(),
        ServeConfig {
            shards: 3,
            reload_poll_ms: 25,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    assert_eq!(client.info().unwrap().nnz as usize, a.nnz());
    b.save(&path).unwrap();
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        if client.info().unwrap().nnz as usize == b.nnz() {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "reload not observed within 10s");
        std::thread::sleep(Duration::from_millis(20));
    }
    // EVERY shard answers from the new model (fresh connections land on
    // whichever shard wins the accept race; multi-row exercises the
    // event path).
    let mut eng = InferEngine::new(&b, 1);
    let mut scratch = TopKScratch::default();
    let mut want = Vec::new();
    let mut rng = Rng::new(25);
    for _ in 0..6 {
        let mut c = Client::connect(server.addr()).unwrap();
        let x: Vec<f32> = (0..784 * 2).map(|_| rng.next_f32()).collect();
        let rows = c.infer_batch(&x, 2, 10, 0).unwrap();
        for (r, got) in rows.iter().enumerate() {
            top_k(eng.forward(&b, &x[r * 784..(r + 1) * 784], 1), 10, &mut scratch, &mut want);
            for ((gc, gl), (wc, wl)) in got.iter().zip(&want) {
                assert_eq!(gc, wc);
                assert_eq!(gl.to_bits(), wl.to_bits());
            }
        }
    }
    // 6 multi-row frames = 6 batcher jobs (a frame is one unit).
    let (reqs, _) = server.stats();
    assert!(reqs >= 6, "expected ≥6 served frames, got {reqs}");
    // Drain with an idle client still connected: idle conns close
    // immediately, so the drain completes inside its budget.
    assert!(server.drain(), "sharded drain did not complete in bound");
    std::fs::remove_file(&path).ok();
}

/// `max_requests` makes the server self-terminating — the CI smoke
/// test's clean-shutdown mechanism — and the load generator sees every
/// reply first.
#[test]
fn max_requests_terminates_cleanly_after_replies() {
    let model = lenet(9, 0.9);
    let server = Server::start(
        model,
        None,
        ServeConfig {
            max_requests: 5,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let addr = server.addr().to_string();
    let stats = run_load(&addr, 1, 5, 1).unwrap();
    assert_eq!(stats.requests, 5);
    assert!(stats.rps > 0.0 && stats.p99_us >= stats.p50_us);
    // The accept loop stops on its own; wait() must return.
    server.wait();
}
