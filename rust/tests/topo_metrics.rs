//! Topology-observability contract suite: the `obs::topo` recorder must
//! never change numerics, never allocate on the steady-state record
//! path, and report metrics that match hand-computed oracles.
//!
//! Everything here is hermetic (in-code models, synthetic data) and
//! serializes on a process-wide lock because several tests toggle the
//! *global* obs enable flag — same discipline as `obs_determinism.rs`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use rigl::backend::native::{mlp_def, NativeBackend};
use rigl::coordinator::ExpContext;
use rigl::model::{ElemType, Kind, ModelDef, Optimizer, ParamSet, ParamSpec, Task};
use rigl::obs::topo::{
    deg_bucket, deg_percentile, nnstd_distance, parse_records, record_json, render_report,
    TopoRecorder, TopoRunMeta, DEG_BUCKETS,
};
use rigl::obs::{self, trace};
use rigl::pool::KernelPool;
use rigl::topology::{update_masks, Grow, GrowOverride, Method};
use rigl::train::{TrainConfig, Trainer};
use rigl::util::Rng;
use rigl::BackendKind;

/// Counting allocator: the zero-steady-state-allocation gate is an
/// exact count of alloc + realloc events, not a heuristic. Dealloc is
/// uncounted — dropping a warm buffer is fine; *acquiring* one on the
/// hot path is not.
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_events() -> u64 {
    ALLOC_EVENTS.load(Ordering::Relaxed)
}

/// Process-wide serialization: tests that flip the global obs flag or
/// measure allocations must not interleave. Poison-tolerant.
static LOCK: Mutex<()> = Mutex::new(());

fn serialize() -> MutexGuard<'static, ()> {
    LOCK.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Restores the global enable/arm flags on drop.
struct FlagGuard {
    enabled: bool,
    armed: bool,
}

impl FlagGuard {
    fn set(enabled: bool, armed: bool) -> FlagGuard {
        FlagGuard { enabled: obs::set_enabled(enabled), armed: trace::set_armed(armed) }
    }
}

impl Drop for FlagGuard {
    fn drop(&mut self) {
        obs::set_enabled(self.enabled);
        trace::set_armed(self.armed);
    }
}

/// Single-FC-layer toy model: `rows × cols` weight matrix, flat element
/// `i` at (row i / cols, col i % cols).
fn toy_def(rows: usize, cols: usize) -> ModelDef {
    ModelDef {
        name: "topo_toy".into(),
        backend: "jnp".into(),
        optimizer: Optimizer::SgdMomentum,
        task: Task::Classify,
        input_ty: ElemType::F32,
        input_shape: vec![1, rows],
        target_shape: vec![1],
        hyper: vec![],
        artifacts: vec![],
        specs: vec![ParamSpec {
            name: "w".into(),
            kind: Kind::Fc,
            sparsifiable: true,
            first_layer: false,
            flops: 0.0,
            shape: vec![rows, cols],
        }],
    }
}

fn masks_with(def: &ModelDef, active: &[usize]) -> ParamSet {
    let mut m = ParamSet::zeros(def);
    for &i in active {
        m.tensors[0][i] = 1.0;
    }
    m
}

// ---------------------------------------------------------------------------
// Hand-computed oracles: NNSTD distance, degree bucketing, half-life.
// ---------------------------------------------------------------------------

#[test]
fn nnstd_cross_seed_distance_matches_hand_oracle() {
    // 4×4 diagonal: column c's incoming set is {row c}.
    let a = vec![(1u64 << 0) | (1 << 5) | (1 << 10) | (1 << 15)];
    // Column-rotated diagonal: col0←{r1}, col1←{r2}, col2←{r3}, col3←{r0}
    // (flat indices 4, 9, 14, 3). Every a-column has an identical
    // b-column under permutation, so the matched distance is exactly 0 —
    // NNSTD is invariant to neuron reordering.
    let b = vec![(1u64 << 4) | (1 << 9) | (1 << 14) | (1 << 3)];
    assert_eq!(nnstd_distance(4, 4, &a, &a), 0.0);
    assert_eq!(nnstd_distance(4, 4, &a, &b), 0.0);

    // 4×2 partial overlap, every pair hand-computable. a: col0 = {r0,r1}
    // (flat 0, 2), col1 = {r2,r3} (flat 5, 7). b: col0 = {r0,r2}
    // (flat 0, 4), col1 = {r1,r3} (flat 3, 7). Every (a_i, b_j) pair
    // shares exactly 1 of 3 union rows → distance 2/3; any matching
    // averages to 2/3.
    let a2 = vec![(1u64 << 0) | (1 << 2) | (1 << 5) | (1 << 7)];
    let b2 = vec![(1u64 << 0) | (1 << 4) | (1 << 3) | (1 << 7)];
    let d = nnstd_distance(4, 2, &a2, &b2);
    assert!((d - 2.0 / 3.0).abs() < 1e-9, "d={d}");
}

#[test]
fn degree_bucketing_matches_naive_log2_oracle() {
    for d in 0u32..70_000 {
        let expect = if d < 2 {
            0
        } else {
            ((d as f64).log2().floor() as usize).min(DEG_BUCKETS - 1)
        };
        assert_eq!(deg_bucket(d), expect, "d={d}");
    }
    // Percentiles report the inclusive bucket upper bound at rank
    // ceil(q·n): 2 obs in bucket 0 (degrees ≤ 1), 3 in bucket 2
    // (degrees 4–7) → n = 5, p50 rank 3 lands in bucket 2 (ceil 7),
    // p20 rank 1 in bucket 0 (ceil 1).
    let mut hist = [0u32; DEG_BUCKETS];
    hist[0] = 2;
    hist[2] = 3;
    assert_eq!(deg_percentile(&hist, 0.20), 1);
    assert_eq!(deg_percentile(&hist, 0.50), 7);
    assert_eq!(deg_percentile(&hist, 1.0), 7);
    assert_eq!(deg_percentile(&[0u32; DEG_BUCKETS], 0.5), 0);
}

#[test]
fn survivor_half_life_crosses_at_known_update() {
    let _g = serialize();
    let _flags = FlagGuard::set(true, false);
    // 4×4 diagonal start, nnz0 = 4. Three updates each net-drop one
    // original connection: survivor fraction 0.75 → 0.50 → 0.25, so
    // the half-life crossing (first update with fraction < 0.5) is
    // update index 2.
    let def = toy_def(4, 4);
    let masks = masks_with(&def, &[0, 5, 10, 15]);
    let mut rec = TopoRecorder::new(&def, &masks, 8);
    rec.record_layer(0, &[0], &[1]);
    rec.end_update(5);
    rec.record_layer(0, &[5], &[4]);
    rec.end_update(10);
    rec.record_layer(0, &[10], &[6]);
    rec.end_update(15);
    let m = rec.finish().unwrap();
    let l = &m.layers[0];
    assert_eq!(l.nnz, vec![4, 4, 4], "balanced swaps must hold nnz");
    assert_eq!(l.survivor_frac, vec![0.75, 0.5, 0.25]);
    assert_eq!(l.survivor_frac.iter().position(|&f| f < 0.5), Some(2));

    // The same oracle survives the record → parse → report roundtrip.
    let meta = TopoRunMeta {
        model: "toy",
        strategy: "set",
        grow: "random",
        sparsity: 0.75,
        decay: "cosine",
        delta_t: 5,
        steps: 20,
        seed: 0,
    };
    let recs = parse_records(&record_json(&meta, &m, None));
    assert_eq!(recs.len(), 1);
    let r = &recs[0];
    assert_eq!(r.layers[0].survivor_frac, vec![0.75, 0.5, 0.25]);
    let report = render_report(&recs);
    assert!(report.contains("set"), "{report}");
    assert!(report.contains("random"), "{report}");
}

// ---------------------------------------------------------------------------
// SET / random grow: exact nnz preservation and zero-init of regrowth.
// ---------------------------------------------------------------------------

#[test]
fn random_grow_preserves_exact_per_layer_nnz() {
    // Two-layer toy so the per-layer invariant is distinguishable from
    // a global-total coincidence.
    let mut def = toy_def(16, 8);
    def.specs.push(ParamSpec {
        name: "w2".into(),
        kind: Kind::Fc,
        sparsifiable: true,
        first_layer: false,
        flops: 0.0,
        shape: vec![8, 4],
    });
    for seed in 0..4u64 {
        for &fraction in &[0.1f64, 0.3, 0.5] {
            let mut init_rng = Rng::new(seed ^ 0xBEEF);
            let mut params = ParamSet::zeros(&def);
            let mut masks = ParamSet::zeros(&def);
            let mut active_before: Vec<Vec<bool>> = Vec::new();
            for li in 0..def.specs.len() {
                let n = def.specs[li].size();
                let mut act = vec![false; n];
                for i in 0..n {
                    // ~50% sparse random init; active weights nonzero.
                    if init_rng.next_f32() < 0.5 {
                        masks.tensors[li][i] = 1.0;
                        params.tensors[li][i] = init_rng.next_f32() + 0.1;
                        act[i] = true;
                    }
                }
                active_before.push(act);
            }
            let nnz_before: Vec<usize> = masks
                .tensors
                .iter()
                .map(|t| t.iter().filter(|&&m| m != 0.0).count())
                .collect();
            let mut opt = [ParamSet::zeros(&def)];
            let mut rng = Rng::new(seed);
            let stats = update_masks(
                &def,
                &mut params,
                &mut opt,
                &mut masks,
                fraction,
                Grow::Random(&mut rng),
            );
            let nnz_after: Vec<usize> = masks
                .tensors
                .iter()
                .map(|t| t.iter().filter(|&&m| m != 0.0).count())
                .collect();
            assert_eq!(
                nnz_before, nnz_after,
                "per-layer nnz drifted (seed={seed} fraction={fraction})"
            );
            assert_eq!(stats.dropped, stats.grown, "unbalanced swap");
            assert!(stats.grown > 0, "degenerate test: nothing moved");
            // Paper §3(4): freshly grown connections start at zero.
            for li in 0..def.specs.len() {
                for (i, &m) in masks.tensors[li].iter().enumerate() {
                    if m != 0.0 && !active_before[li][i] {
                        assert_eq!(
                            params.tensors[li][i], 0.0,
                            "grown weight not zero-initialized (layer {li}, idx {i})"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Allocation: the warm recorder's record path must be allocation-free.
// ---------------------------------------------------------------------------

#[test]
fn recorder_steady_state_allocates_nothing() {
    let _g = serialize();
    let _flags = FlagGuard::set(true, false);
    // 64×64 layer, every 4th element active (1024 connections).
    let def = toy_def(64, 64);
    let active: Vec<usize> = (0..64 * 64).step_by(4).collect();
    let masks = masks_with(&def, &active);
    const UPDATES: usize = 512;
    let mut rec = TopoRecorder::new(&def, &masks, UPDATES + 1);
    // Cold path: first record registers the topo.* counters/histograms
    // in the metrics registry, outside the measured window.
    rec.record_layer(0, &[0], &[1]);
    rec.end_update(0);

    let before = alloc_events();
    for u in 1..=UPDATES {
        // Ping-pong one connection between flat indices 0 and 1 so
        // every drop hits an active index and every grow an inactive
        // one, exactly like a real balanced update.
        let (dropped, grown) = if u % 2 == 1 { ([1u32], [0u32]) } else { ([0u32], [1u32]) };
        rec.record_layer(0, &dropped, &grown);
        rec.end_update(u * 5);
    }
    let after = alloc_events();
    assert_eq!(
        after - before,
        0,
        "warm topo record path allocated {} times in {UPDATES} updates",
        after - before
    );
    let m = rec.finish().unwrap();
    assert_eq!(m.update_steps.len(), UPDATES + 1);
    assert_eq!(m.layers[0].nnz.len(), UPDATES + 1);
    assert!(m.layers[0].nnz.iter().all(|&n| n == 1024));
}

// ---------------------------------------------------------------------------
// Training integration: series populate for the zoo, vanish under
// --no-obs, and never perturb numerics.
// ---------------------------------------------------------------------------

fn small_cfg(method: Method, grow: GrowOverride) -> TrainConfig {
    let mut cfg = TrainConfig::new("topo_mlp", method);
    cfg.sparsity = 0.9;
    cfg.steps = 30;
    cfg.delta_t = 10;
    cfg.augment = false;
    cfg.data_train = 256;
    cfg.data_val = 128;
    cfg.grow = grow;
    cfg
}

/// One full run; returns every parameter tensor as raw bits plus the
/// run result, so comparisons are exact.
fn train_run(
    method: Method,
    grow: GrowOverride,
    obs_on: bool,
    threads: usize,
) -> (Vec<Vec<u32>>, rigl::train::RunResult) {
    let _flags = FlagGuard::set(obs_on, false);
    let cfg = small_cfg(method, grow);
    let def = mlp_def(&cfg.model, 784, &[32], 10, 16);
    let pool = Arc::new(KernelPool::with_par_min_ops(threads, 1));
    let backend = Arc::new(NativeBackend::with_pool(&def, Some(pool)).unwrap());
    let trainer = Trainer::from_parts(def, backend, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    let bits = state
        .params
        .tensors
        .iter()
        .map(|t| t.iter().map(|v| v.to_bits()).collect())
        .collect();
    (bits, r)
}

#[test]
fn topo_series_populate_for_dynamic_methods() {
    let _g = serialize();
    let (_, r) = train_run(Method::Set, GrowOverride::Auto, true, 1);
    let m = r.topo.expect("dynamic run with obs on must record topology");
    assert!(!m.update_steps.is_empty(), "steps=30 ΔT=10 → updates fired");
    assert!(!m.layers.is_empty());
    let n = m.update_steps.len();
    for l in &m.layers {
        // Every series stays parallel to update_steps, including the
        // no-change rows of engine-skipped layers.
        assert_eq!(l.nnz.len(), n, "layer {}", l.name);
        assert_eq!(l.churn.len(), n);
        assert_eq!(l.jaccard.len(), n);
        assert_eq!(l.nnstd.len(), n);
        assert_eq!(l.survivor_frac.len(), n);
        assert_eq!(l.in_deg_hist.len(), n);
        // SET is drop/grow balanced: nnz must not drift from nnz0.
        assert!(l.nnz.iter().all(|&v| v == l.nnz0), "nnz drifted on {}", l.name);
        // Survivor fraction is monotone non-increasing by construction.
        for w in l.survivor_frac.windows(2) {
            assert!(w[1] <= w[0] + 1e-6, "survivor fraction rose on {}", l.name);
        }
        for (&c, &j) in l.churn.iter().zip(&l.jaccard) {
            assert!((0.0..=1.0).contains(&c) && (0.0..=1.0).contains(&j));
        }
        // The degree histograms account for every row/column.
        let cols: u64 = l.in_deg_final.iter().map(|&c| c as u64).sum();
        let rows: u64 = l.out_deg_final.iter().map(|&c| c as u64).sum();
        assert_eq!(cols, l.cols as u64);
        assert_eq!(rows, l.rows as u64);
    }
}

#[test]
fn static_control_records_masks_but_no_updates() {
    let _g = serialize();
    // `--grow static` on a dynamic method freezes the topology but
    // still snapshots it: empty series, valid final degree histograms
    // and active bitmaps (the cross-seed NNSTD baseline).
    let (_, r) = train_run(Method::Rigl, GrowOverride::Static, true, 1);
    let m = r.topo.expect("static control still snapshots the topology");
    assert!(m.update_steps.is_empty(), "static control must not record updates");
    assert!(!m.layers.is_empty());
    for l in &m.layers {
        assert!(l.nnz0 > 0);
        assert!(l.nnz.is_empty());
        let ones: u64 = l.final_active.iter().map(|w| w.count_ones() as u64).sum();
        assert_eq!(ones, l.nnz0, "final_active must equal the frozen mask");
        let cols: u64 = l.in_deg_final.iter().map(|&c| c as u64).sum();
        assert_eq!(cols, l.cols as u64);
    }
    assert_eq!(r.obs.updates, 0, "static control must not update masks");

    let (_, off) = train_run(Method::Set, GrowOverride::Auto, false, 1);
    assert!(off.topo.is_none(), "--no-obs must suppress the recorder entirely");
}

#[test]
fn training_is_bit_identical_with_recorder_on_off_across_threads() {
    let _g = serialize();
    // SET is the sharpest probe: its grow draws RNG, so any recorder
    // interference with the random stream would move the topology.
    let (base_bits, base_r) = train_run(Method::Set, GrowOverride::Auto, true, 1);
    for (obs_on, threads) in [(false, 1), (true, 8), (false, 8)] {
        let (bits, r) = train_run(Method::Set, GrowOverride::Auto, obs_on, threads);
        assert_eq!(
            bits, base_bits,
            "params diverged at obs={obs_on} threads={threads}"
        );
        assert_eq!(r.final_train_loss.to_bits(), base_r.final_train_loss.to_bits());
        assert_eq!(r.total_swapped, base_r.total_swapped);
    }
}

#[test]
fn coordinator_runs_bit_identical_across_jobs_threads_and_obs() {
    let _g = serialize();
    // The acceptance matrix: --jobs {1,4} × --threads {1,8}, recorder
    // on and off, through the real coordinator fan-out. Fingerprints
    // are raw f64 bits of every per-seed loss trajectory.
    let run = |jobs: usize, threads: usize, obs_on: bool| -> Vec<(u64, u64, Vec<u64>)> {
        let _flags = FlagGuard::set(obs_on, false);
        let out = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target/topo_test");
        let mut ctx = ExpContext::with_backend(2, 1.0, jobs, out, BackendKind::Native)
            .unwrap()
            .with_threads(threads);
        ctx.verbose = false;
        let mut cfg = ctx.base("mlp", Method::Set);
        cfg.sparsity = 0.9;
        cfg.steps = 20;
        cfg.delta_t = 5;
        cfg.augment = false;
        cfg.data_train = 128;
        cfg.data_val = 64;
        let full = ctx.run_cells_full(&[("cell".into(), cfg)]).unwrap();
        assert_eq!(full.len(), 1);
        assert_eq!(full[0].len(), 2, "two seeds expected");
        full[0]
            .iter()
            .map(|r| {
                assert_eq!(
                    r.topo.is_some(),
                    obs_on,
                    "recorder presence must track the obs flag"
                );
                (
                    r.final_train_loss.to_bits(),
                    r.final_metric.to_bits(),
                    r.loss_history.iter().map(|&(_, l)| l.to_bits()).collect(),
                )
            })
            .collect()
    };
    let base = run(1, 1, true);
    for (jobs, threads, obs_on) in
        [(4, 1, true), (1, 8, true), (4, 8, true), (1, 1, false), (4, 8, false)]
    {
        let got = run(jobs, threads, obs_on);
        assert_eq!(
            got, base,
            "run diverged at jobs={jobs} threads={threads} obs={obs_on}"
        );
    }
}
