//! Backend contract tests: the native CSR engine end to end, and (when
//! PJRT artifacts are available) native-vs-pjrt trajectory parity.
//!
//! The native half is hermetic — models are built in code via
//! `backend::native::mlp_def`, data is synthetic — so these run on a
//! bare CPU with no XLA install and no `make artifacts` (the `--no-pjrt`
//! CI path). The parity half auto-skips without artifacts.

use std::sync::Arc;

use rigl::backend::native::{mlp_def, NativeBackend};
use rigl::backend::BackendKind;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};

fn native_trainer(hidden: &[usize], batch: usize, cfg: &TrainConfig) -> Trainer {
    let def = mlp_def(&cfg.model, 784, hidden, 10, batch);
    let backend = Arc::new(NativeBackend::new(&def).unwrap());
    Trainer::from_parts(def, backend, cfg).unwrap()
}

fn tiny_cfg(method: Method) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny_mlp", method);
    cfg.sparsity = 0.9;
    cfg.steps = 200;
    cfg.delta_t = 40;
    cfg.augment = false;
    cfg.data_train = 512;
    cfg.data_val = 256;
    cfg
}

#[test]
fn native_rigl_trains_end_to_end() {
    let cfg = tiny_cfg(Method::Rigl);
    let trainer = native_trainer(&[32], 32, &cfg);
    assert_eq!(trainer.backend_kind(), BackendKind::Native);
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();

    // Finite, decreasing loss.
    assert!(r.final_train_loss.is_finite());
    for (_, l) in &r.loss_history {
        assert!(l.is_finite(), "non-finite loss in history");
    }
    let first = r.loss_history.first().unwrap().1;
    assert!(
        r.final_train_loss < first,
        "loss did not decrease: {first} → {}",
        r.final_train_loss
    );
    // Learns something real (chance accuracy is 0.1 on 10 classes).
    assert!(r.final_metric > 0.3, "accuracy {}", r.final_metric);
    // Topology actually rewired and overall sparsity held.
    assert!(r.total_swapped > 0, "no topology updates happened");
    assert!(
        (r.final_sparsity - 0.9).abs() < 0.01,
        "sparsity drifted: {}",
        r.final_sparsity
    );

    // params == params·mask must hold exactly after the run.
    for (i, spec) in trainer.def.specs.iter().enumerate() {
        if !spec.sparsifiable {
            continue;
        }
        for (p, m) in state.params.tensors[i].iter().zip(&state.masks.tensors[i]) {
            if *m == 0.0 {
                assert_eq!(*p, 0.0, "pruned weight resurrected in {}", spec.name);
            }
        }
    }
}

#[test]
fn native_nnz_conserved_exactly_across_mask_updates() {
    let cfg = tiny_cfg(Method::Rigl);
    let trainer = native_trainer(&[48, 24], 16, &cfg);
    let mut state = trainer.init_state(&cfg);
    let before: Vec<usize> = (0..trainer.def.specs.len())
        .map(|i| state.masks.nnz(i))
        .collect();
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    assert!(r.total_swapped > 0, "test needs at least one mask update");
    for (i, spec) in trainer.def.specs.iter().enumerate() {
        // Incremental count must equal a fresh scan AND the initial
        // cardinality: RigL drops and grows in equal measure.
        let scan = state.masks.tensors[i]
            .iter()
            .filter(|&&v| v != 0.0)
            .count();
        assert_eq!(state.masks.nnz(i), scan, "tracked nnz drifted in {}", spec.name);
        assert_eq!(
            scan, before[i],
            "nnz not conserved in {} ({} → {scan})",
            spec.name, before[i]
        );
    }
}

#[test]
fn native_set_and_static_methods_run() {
    for method in [Method::Set, Method::Static, Method::Dense] {
        let mut cfg = tiny_cfg(method);
        cfg.steps = 60;
        cfg.delta_t = 15;
        let trainer = native_trainer(&[24], 16, &cfg);
        let r = trainer.run(&cfg).unwrap();
        assert!(r.final_train_loss.is_finite(), "{method:?}");
        assert!(r.final_metric > 0.1, "{method:?}: {}", r.final_metric);
        if method == Method::Set {
            assert!(r.total_swapped > 0);
        }
        if method == Method::Dense {
            assert_eq!(r.final_sparsity, 0.0);
        }
    }
}

#[test]
fn native_is_deterministic() {
    let cfg = tiny_cfg(Method::Rigl);
    let trainer = native_trainer(&[24], 16, &cfg);
    let a = trainer.run(&cfg).unwrap();
    let b = trainer.run(&cfg).unwrap();
    assert_eq!(a.final_metric, b.final_metric);
    assert_eq!(a.final_train_loss, b.final_train_loss);
    assert_eq!(a.total_swapped, b.total_swapped);
}

/// Native and PJRT execute the same math on the same data: short
/// trajectories must agree to float-reordering tolerance. Auto-skips
/// when the AOT artifacts are absent.
#[cfg(feature = "pjrt")]
#[test]
fn native_matches_pjrt_losses() {
    use rigl::model::load_manifest;
    use rigl::Runtime;

    let dir = rigl::artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping backend parity: artifacts not built");
        return;
    }
    let manifest = load_manifest(&dir).unwrap();
    let rt = Runtime::cpu().unwrap();

    let mut cfg = TrainConfig::new("mlp", Method::Static);
    cfg.sparsity = 0.9;
    cfg.steps = 40;
    cfg.augment = false;
    cfg.data_train = 512;
    cfg.data_val = 256;

    let pjrt = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let native = Trainer::native(&manifest, &cfg).unwrap();
    assert_eq!(pjrt.backend_kind(), BackendKind::Pjrt);
    assert_eq!(native.backend_kind(), BackendKind::Native);

    let rp = pjrt.run(&cfg).unwrap();
    let rn = native.run(&cfg).unwrap();

    assert_eq!(rp.loss_history.len(), rn.loss_history.len());
    for ((tp, lp), (tn, ln)) in rp.loss_history.iter().zip(&rn.loss_history) {
        assert_eq!(tp, tn);
        assert!(
            (lp - ln).abs() < 0.05,
            "loss diverged at step {tp}: pjrt {lp} vs native {ln}"
        );
    }
    assert!(
        (rp.final_metric - rn.final_metric).abs() < 0.1,
        "metric diverged: pjrt {} vs native {}",
        rp.final_metric,
        rn.final_metric
    );

    // RigL end-to-end on both backends: same sparsity invariants even if
    // float noise flips individual grow choices over time.
    let mut cfg_r = cfg.clone();
    cfg_r.method = Method::Rigl;
    cfg_r.delta_t = 10;
    let rr_p = pjrt.run(&cfg_r).unwrap();
    let rr_n = native.run(&cfg_r).unwrap();
    assert!((rr_p.final_sparsity - rr_n.final_sparsity).abs() < 1e-6);
    assert!(rr_n.total_swapped > 0);
}
