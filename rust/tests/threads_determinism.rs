//! Thread-count determinism suite: the blocked parallel kernels must be
//! a pure wall-clock knob. Whole native training runs and serve
//! forwards are asserted BIT-identical across `--threads {1, 2, 8}` and
//! across block layouts, and the incrementally patched per-block nnz
//! counts are property-tested against from-scratch recounts over long
//! randomized drop/grow sequences.
//!
//! Hermetic: models built in code, synthetic data, no artifacts, no
//! PJRT — runs on the `--no-pjrt` CI path.

use std::sync::Arc;

use rigl::backend::native::csr::{CsrScratch, CsrTopo};
use rigl::backend::native::kernels::{spmm_bias_fwd, Exec};
use rigl::backend::native::simd::PanelScratch;
use rigl::backend::native::{mlp_def, NativeBackend};
use rigl::pool::KernelPool;
use rigl::serve::{InferEngine, SparseModel};
use rigl::sparsity::Distribution;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::util::Rng;

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// One full RigL run (mask updates included) at a given thread count:
/// returns the final state's tensors plus the loss history, all as
/// bits.
fn run_rigl(threads: usize) -> (Vec<Vec<u32>>, Vec<u64>, u64, usize) {
    let mut cfg = TrainConfig::new("det_mlp", Method::Rigl);
    cfg.sparsity = 0.9;
    cfg.steps = 100;
    cfg.delta_t = 25;
    cfg.augment = false;
    cfg.data_train = 512;
    cfg.data_val = 256;
    cfg.threads = threads;
    // Sized past the kernels' autotune floor so pools genuinely engage.
    let def = mlp_def(&cfg.model, 784, &[96, 48], 10, 32);
    let backend = Arc::new(NativeBackend::with_threads(&def, threads).unwrap());
    let trainer = Trainer::from_parts(def, backend, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    let tensors: Vec<Vec<u32>> = state
        .params
        .tensors
        .iter()
        .chain(state.opt[0].tensors.iter())
        .chain(state.masks.tensors.iter())
        .map(|t| bits32(t))
        .collect();
    let losses: Vec<u64> = r.loss_history.iter().map(|(_, l)| l.to_bits()).collect();
    (tensors, losses, r.final_train_loss.to_bits(), r.total_swapped)
}

/// The headline contract: an entire native training run — forward,
/// backward, optimizer, topology updates, CSR patching — is
/// bit-identical at any `--threads`.
#[test]
fn native_rigl_run_bit_identical_across_thread_counts() {
    let (t1, l1, fl1, sw1) = run_rigl(1);
    for threads in [2usize, 8] {
        let (t, l, fl, sw) = run_rigl(threads);
        assert_eq!(sw, sw1, "topology diverged at threads={threads}");
        assert_eq!(l, l1, "loss history diverged at threads={threads}");
        assert_eq!(fl, fl1, "final train loss diverged at threads={threads}");
        for (i, (a, b)) in t.iter().zip(&t1).enumerate() {
            assert_eq!(a, b, "tensor {i} diverged at threads={threads}");
        }
    }
}

/// Serve forwards are bit-identical across thread counts AND across
/// block layouts — the decomposition is a schedule, never a different
/// computation.
#[test]
fn serve_forward_bit_identical_across_threads_and_block_sizes() {
    let def = mlp_def("mlp", 784, &[300, 100], 10, 1);
    let mut model = SparseModel::init_random(&def, 0.9, &Distribution::Uniform, 0xD7).unwrap();
    let mut rng = Rng::new(0xD8);
    let batch = 3;
    let x: Vec<f32> = (0..batch * 784).map(|_| rng.next_f32()).collect();

    let mut ser = InferEngine::new(&model, batch);
    let want = bits32(ser.forward(&model, &x, batch));

    for threads in [2usize, 4, 8] {
        // Sweep block layouts, including degenerate single-block.
        for &(target, maxb) in &[(64usize, 32usize), (1024, 8), (usize::MAX, 16)] {
            for layer in &mut model.layers {
                layer.topo.build_blocks_with(target, maxb);
            }
            let pool = Arc::new(KernelPool::with_par_min_ops(threads, 1));
            let mut eng = InferEngine::new(&model, batch);
            eng.set_pool(Some(pool));
            let got = bits32(eng.forward(&model, &x, batch));
            assert_eq!(
                got, want,
                "diverged at threads={threads} target={target} maxb={maxb}"
            );
        }
    }
}

/// Property test: after arbitrary randomized drop/grow sequences, the
/// incrementally patched per-block nnz counts must equal a from-scratch
/// recount of the (independently verified) structure, and the patched
/// decomposition must drive the parallel kernels to serial-identical
/// results.
#[test]
fn patched_block_counts_match_rebuild_under_random_swaps() {
    let mut rng = Rng::new(0xB10C);
    // Floor pinned to 1 so the pooled path engages on any machine.
    let pool = KernelPool::with_par_min_ops(4, 1);
    for case in 0..6 {
        // Sized so batch·nnz clears the kernels' autotune floor and the
        // pooled forward below truly runs the patched blocked path.
        let rows = 150 + rng.next_below(100);
        let cols = 100 + rng.next_below(60);
        let mut mask: Vec<f32> = (0..rows * cols)
            .map(|_| if rng.next_f64() < 0.35 { 1.0 } else { 0.0 })
            .collect();
        let mut topo = CsrTopo::from_mask(&mask, rows, cols);
        topo.build_blocks_with(32, 8);
        let mut scratch = CsrScratch::default();

        for step in 0..40 {
            // Random legal swap: dropped ⊆ active, grown ⊆ inactive.
            let active: Vec<u32> = (0..mask.len())
                .filter(|&i| mask[i] != 0.0)
                .map(|i| i as u32)
                .collect();
            let mut dropped = active.clone();
            rng.shuffle(&mut dropped);
            dropped.truncate(rng.next_below(active.len().max(1)) / 2);
            for &i in &dropped {
                mask[i as usize] = 0.0;
            }
            let mut grown: Vec<u32> = (0..mask.len())
                .filter(|&i| mask[i] == 0.0)
                .map(|i| i as u32)
                .collect();
            rng.shuffle(&mut grown);
            grown.truncate(dropped.len()); // RigL-style conservation
            for &i in &grown {
                mask[i as usize] = 1.0;
            }
            topo.apply_swap(&dropped, &grown, &mut scratch);

            // Structure equals a from-scratch rebuild.
            let fresh = CsrTopo::from_mask(&mask, rows, cols);
            assert_eq!(topo.row_ptr, fresh.row_ptr, "case {case} step {step}");
            assert_eq!(topo.col_idx, fresh.col_idx, "case {case} step {step}");

            // Patched counts equal a recount over the live boundaries.
            let b = &topo.blocks;
            assert_eq!(*b.row_blk.last().unwrap() as usize, rows);
            for (t, w) in b.row_blk.windows(2).enumerate() {
                let want = topo.row_ptr[w[1] as usize] - topo.row_ptr[w[0] as usize];
                assert_eq!(b.rb_nnz[t], want, "case {case} step {step} block {t}");
            }
            assert_eq!(
                b.rb_nnz.iter().map(|&n| n as usize).sum::<usize>(),
                fresh.nnz(),
                "case {case} step {step}: total drifted"
            );

            // Column sub-ranges bracket exactly the in-block entries.
            let ncb = b.n_col_blocks();
            if ncb > 1 {
                for r in 0..rows {
                    for j in 0..ncb {
                        let (s, e) = topo.cb_range(r, j);
                        for &c in &topo.col_idx[s..e] {
                            assert!(c >= b.col_blk[j] && c < b.col_blk[j + 1]);
                        }
                    }
                    assert_eq!(topo.cb_range(r, ncb - 1).1, topo.row_ptr[r + 1] as usize);
                }
            }

            // And the patched decomposition computes correctly.
            if step % 10 == 0 {
                let batch = 4;
                let w: Vec<f32> = (0..rows * cols).map(|_| rng.next_f32() - 0.5).collect();
                let xin: Vec<f32> = (0..batch * rows).map(|_| rng.next_f32()).collect();
                let bias: Vec<f32> = (0..cols).map(|_| rng.next_f32()).collect();
                let mut y_ser = vec![0.0f32; batch * cols];
                let mut panels = PanelScratch::default();
                spmm_bias_fwd(Exec::Serial, &xin, batch, &topo, &w, &bias, &mut y_ser, &mut panels);
                let mut y_par = vec![1.0f32; batch * cols];
                spmm_bias_fwd(
                    Exec::Pool(&pool),
                    &xin,
                    batch,
                    &topo,
                    &w,
                    &bias,
                    &mut y_par,
                    &mut panels,
                );
                assert_eq!(bits32(&y_par), bits32(&y_ser), "case {case} step {step}");
            }
        }
    }
}
