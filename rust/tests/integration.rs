//! Integration tests: the full L3 stack against real AOT artifacts.
//!
//! These run short trainings on the MLP track (the fastest artifacts) and
//! assert the semantic properties every experiment depends on. Skipped
//! gracefully when `make artifacts` has not run, and compiled out
//! entirely without the `pjrt` feature (the hermetic native-backend
//! suite lives in `backend_parity.rs`).
#![cfg(feature = "pjrt")]

use rigl::coordinator::ExpContext;
use rigl::model::{load_checkpoint, load_manifest, save_checkpoint, Checkpoint, Manifest};
use rigl::sparsity::Distribution;
use rigl::topology::Method;
use rigl::train::replica::{run_replicated, ReplicaBugs, ReplicaConfig};
use rigl::train::{TrainConfig, Trainer};
use rigl::util::Rng;
use rigl::Runtime;

fn setup() -> Option<(Runtime, Manifest)> {
    let dir = rigl::artifacts_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping integration tests: artifacts not built");
        return None;
    }
    Some((Runtime::cpu().unwrap(), load_manifest(&dir).unwrap()))
}

fn mlp_cfg(method: Method) -> TrainConfig {
    let mut cfg = TrainConfig::new("mlp", method);
    cfg.sparsity = 0.9;
    cfg.steps = 120;
    cfg.delta_t = 30;
    cfg.augment = false;
    cfg.data_train = 512;
    cfg.data_val = 256;
    cfg
}

#[test]
fn rigl_learns_and_stays_sparse() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = mlp_cfg(Method::Rigl);
    let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    assert!(r.final_metric > 0.5, "accuracy {}", r.final_metric);
    assert!(
        (r.final_sparsity - 0.9).abs() < 0.01,
        "sparsity drifted: {}",
        r.final_sparsity
    );
    assert!(r.total_swapped > 0, "no topology updates happened");
    // The params == params·mask invariant must hold exactly.
    for (i, spec) in trainer.def.specs.iter().enumerate() {
        if !spec.sparsifiable {
            continue;
        }
        for (p, m) in state.params.tensors[i].iter().zip(&state.masks.tensors[i]) {
            if *m == 0.0 {
                assert_eq!(*p, 0.0, "pruned weight resurrected in {}", spec.name);
            }
        }
    }
    // FLOPs accounting: RigL at ΔT=25 must sit between static and SNFS.
    assert!(r.train_flops_ratio > 0.09 && r.train_flops_ratio < 0.5);
}

#[test]
fn method_ordering_static_vs_rigl() {
    let Some((rt, manifest)) = setup() else { return };
    let trainer = Trainer::new(&rt, &manifest, &mlp_cfg(Method::Rigl)).unwrap();
    // 99%-sparse first layer stresses topology search; static should lag.
    let mut cfg_s = mlp_cfg(Method::Static);
    cfg_s.sparsity = 0.97;
    let mut cfg_r = cfg_s.clone();
    cfg_r.method = Method::Rigl;
    let acc_s = trainer.run(&cfg_s).unwrap().final_metric;
    let acc_r = trainer.run(&cfg_r).unwrap().final_metric;
    // RigL should never be (meaningfully) worse.
    assert!(
        acc_r >= acc_s - 0.02,
        "RigL {acc_r} worse than Static {acc_s}"
    );
}

#[test]
fn snip_mask_uses_saliency() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = mlp_cfg(Method::Snip);
    let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    // SNIP ends at the target sparsity even though it starts dense.
    assert!((r.final_sparsity - 0.9).abs() < 0.01, "{}", r.final_sparsity);
    assert!(r.final_metric > 0.4, "{}", r.final_metric);
}

#[test]
fn pruning_ramps_to_target() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = mlp_cfg(Method::Pruning);
    let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state).unwrap();
    assert!(
        (r.final_sparsity - 0.9).abs() < 0.02,
        "pruning missed target: {}",
        r.final_sparsity
    );
    assert!(r.final_metric > 0.5, "{}", r.final_metric);
    // Appendix H: pruning costs more than sparse-from-scratch training.
    assert!(r.train_flops_ratio > 0.3, "{}", r.train_flops_ratio);
}

#[test]
fn adam_gru_track_runs() {
    let Some((rt, manifest)) = setup() else { return };
    let mut cfg = TrainConfig::new("gru", Method::Rigl);
    cfg.sparsity = 0.75;
    cfg.steps = 60;
    cfg.delta_t = 15;
    cfg.t_end_frac = 1.0;
    let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let r = trainer.run(&cfg).unwrap();
    // bits/char must beat the uniform bound (6 bits) after 60 steps.
    assert!(r.final_metric < 6.0, "bits {}", r.final_metric);
    assert!(r.final_metric > 0.0);
}

#[test]
fn pallas_and_jnp_artifacts_agree() {
    // The same training run through the pallas-kernel artifacts and the
    // jnp artifacts must produce identical trajectories (the programs are
    // semantically equal; both run on the same PJRT CPU backend).
    let Some((rt, manifest)) = setup() else { return };
    let mut accs = Vec::new();
    for model in ["mlp", "mlp_pallas"] {
        let mut cfg = mlp_cfg(Method::Rigl);
        cfg.model = model.to_string();
        cfg.steps = 40;
        let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
        let r = trainer.run(&cfg).unwrap();
        accs.push(r.final_metric);
    }
    assert!(
        (accs[0] - accs[1]).abs() < 0.02,
        "jnp {} vs pallas {}",
        accs[0],
        accs[1]
    );
}

#[test]
fn replica_sim_fixed_has_zero_divergence() {
    let Some((rt, manifest)) = setup() else { return };
    let mut cfg = mlp_cfg(Method::Rigl);
    cfg.steps = 60;
    let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let fixed = run_replicated(
        &trainer,
        &cfg,
        &ReplicaConfig {
            replicas: 2,
            bugs: ReplicaBugs::default(),
            broadcast_every: 0,
        },
    )
    .unwrap();
    assert_eq!(
        fixed.mask_divergence, 0.0,
        "all-reduced RigL replicas must agree on topology"
    );
    let buggy = run_replicated(
        &trainer,
        &cfg,
        &ReplicaConfig {
            replicas: 2,
            bugs: ReplicaBugs {
                desync_rng: false,
                skip_grad_allreduce: true,
            },
            broadcast_every: 0,
        },
    )
    .unwrap();
    assert!(
        buggy.mask_divergence > 0.0,
        "skipping the grad all-reduce must desync masks"
    );
}

#[test]
fn warm_start_resumes_from_checkpoint() {
    let Some((rt, manifest)) = setup() else { return };
    let cfg = mlp_cfg(Method::Rigl);
    let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let mut state = trainer.init_state(&cfg);
    trainer.run_from(&cfg, &mut state).unwrap();

    let path = std::env::temp_dir().join(format!("rigl_it_ckpt_{}.bin", std::process::id()));
    save_checkpoint(
        &path,
        &Checkpoint {
            step: state.step as u64,
            sets: vec![state.params.clone(), state.masks.clone(), state.opt[0].clone()],
        },
    )
    .unwrap();
    let back = load_checkpoint(&path).unwrap();
    assert_eq!(back.step, state.step as u64);
    let mut resumed = trainer.init_state(&cfg);
    resumed.params = back.sets[0].clone();
    resumed.masks = back.sets[1].clone();
    resumed.opt[0] = back.sets[2].clone();
    // Warm model should evaluate identically to the saved one.
    let a = trainer.evaluate(&state, &cfg).unwrap();
    let b = trainer.evaluate(&resumed, &cfg).unwrap();
    assert!((a - b).abs() < 1e-9);
    std::fs::remove_file(&path).ok();
}

#[test]
fn determinism_same_seed_same_result() {
    let Some((rt, manifest)) = setup() else { return };
    let mut cfg = mlp_cfg(Method::Set);
    cfg.steps = 50;
    let trainer = Trainer::new(&rt, &manifest, &cfg).unwrap();
    let a = trainer.run(&cfg).unwrap();
    let b = trainer.run(&cfg).unwrap();
    assert_eq!(a.final_metric, b.final_metric);
    assert_eq!(a.total_swapped, b.total_swapped);
    let mut cfg2 = cfg.clone();
    cfg2.seed = 1;
    let c = trainer.run(&cfg2).unwrap();
    // Different seed ⇒ different masks ⇒ (almost surely) different metric.
    assert!(a.final_metric != c.final_metric || a.total_swapped != c.total_swapped);
}

#[test]
fn erk_distribution_changes_flops_not_params() {
    let Some((rt, manifest)) = setup() else { return };
    let mut cfg_u = mlp_cfg(Method::Static);
    cfg_u.steps = 10;
    let mut cfg_e = cfg_u.clone();
    cfg_e.distribution = Distribution::Erk;
    let trainer = Trainer::new(&rt, &manifest, &cfg_u).unwrap();
    let su = trainer.init_state(&cfg_u);
    let se = trainer.init_state(&cfg_e);
    let sparse_idx = trainer.def.sparse_indices();
    let nnz = |s: &rigl::train::TrainState| -> usize {
        sparse_idx.iter().map(|&i| s.masks.nnz(i)).sum()
    };
    // Same parameter budget (±rounding across layers)…
    let (a, b) = (nnz(&su), nnz(&se));
    assert!(
        (a as f64 - b as f64).abs() / a as f64 <= 0.01,
        "uniform {a} vs erk {b}"
    );
    // …but a different layout.
    assert_ne!(su.masks.nnz(0), se.masks.nnz(0));
}

/// A small coordinator context with `jobs` workers (artifact-gated by
/// the caller via `setup`).
fn small_ctx(seeds: usize, jobs: usize) -> ExpContext {
    let mut ctx = ExpContext::new(seeds, 1.0, jobs, std::env::temp_dir()).unwrap();
    ctx.verbose = false;
    ctx
}

fn small_cell_cfg(ctx: &ExpContext, delta_t: usize) -> TrainConfig {
    let mut cfg = ctx.base("mlp", Method::Rigl);
    cfg.sparsity = 0.9;
    cfg.steps = 60;
    cfg.delta_t = delta_t;
    cfg.augment = false;
    cfg.data_train = 512;
    cfg.data_val = 256;
    cfg
}

#[test]
fn parallel_jobs_bit_identical_to_serial() {
    // The determinism contract of the thread-pool refactor: `--jobs 1`
    // and `--jobs 4` must produce byte-identical per-seed results.
    let Some(_) = setup() else { return };
    let run = |jobs: usize| {
        let ctx = small_ctx(3, jobs);
        let cfg = small_cell_cfg(&ctx, 15);
        ctx.run_cell("equivalence", &cfg).unwrap()
    };
    let serial = run(1);
    let parallel = run(4);
    assert_eq!(
        serial.metrics, parallel.metrics,
        "per-seed final_metric must be bit-identical across job counts"
    );
    // `extra` carries per-seed train_loss AND total_swapped in seed order.
    assert_eq!(
        serial.extra, parallel.extra,
        "per-seed train_loss/total_swapped must be identical across job counts"
    );
}

#[test]
fn run_cells_matches_run_cell_and_preserves_order() {
    let Some(_) = setup() else { return };
    let ctx = small_ctx(2, 4);
    let cfg_a = small_cell_cfg(&ctx, 15);
    let cfg_b = small_cell_cfg(&ctx, 30);
    let cells = ctx
        .run_cells(vec![
            ("cell-a".into(), cfg_a.clone()),
            ("cell-b".into(), cfg_b.clone()),
        ])
        .unwrap();
    assert_eq!(cells.len(), 2);
    assert_eq!(cells[0].label, "cell-a");
    assert_eq!(cells[1].label, "cell-b");
    // Grid fan-out must agree with cell-at-a-time execution.
    let a = ctx.run_cell("cell-a", &cfg_a).unwrap();
    let b = ctx.run_cell("cell-b", &cfg_b).unwrap();
    assert_eq!(cells[0].metrics, a.metrics);
    assert_eq!(cells[1].metrics, b.metrics);
}

#[test]
fn rng_streams_match_across_processes() {
    // Guard against accidental RNG-layout changes: pinned values keep
    // experiment seeds reproducible across releases.
    let mut r = Rng::new(42);
    let vals: Vec<u64> = (0..3).map(|_| r.next_u64()).collect();
    assert_eq!(
        vals,
        vec![
            13567298546313804722,
            11184406007107238175,
            4421296945768246786
        ]
    );
}
