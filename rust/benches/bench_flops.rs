//! Appendix-H accounting engine latency (it runs inside every table cell).

use rigl::flops::{train_flops_per_sample, train_flops_ratio};
use rigl::model::load_manifest;
use rigl::prune::PruneSchedule;
use rigl::sparsity::{layer_sparsities, Distribution};
use rigl::topology::Method;
use rigl::util::bench;

fn main() -> anyhow::Result<()> {
    let manifest = load_manifest(&rigl::artifacts_dir())?;
    println!("== bench_flops: per-method accounting ==");
    for model in ["cnn", "wrn"] {
        let def = manifest.get(model)?;
        let s = layer_sparsities(def, 0.9, &Distribution::Erk);
        let sched = PruneSchedule::paper_default(32_000, s.clone());
        for m in [Method::Rigl, Method::Snfs, Method::Pruning] {
            bench(&format!("flops/{model}/{}", m.label()), 100, || {
                let _ = train_flops_per_sample(def, m, &s, 100, Some(&sched), 32_000);
            });
        }
        bench(&format!("flops_ratio/{model}"), 100, || {
            let _ = train_flops_ratio(def, Method::Rigl, &s, 100, None, 32_000, 5.0);
        });
    }
    Ok(())
}
