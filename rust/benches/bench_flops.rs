//! Appendix-H accounting engine latency (it runs inside every table cell).
//!
//! Hermetic: uses the artifacts manifest when present, else the builtin
//! native model zoo (models absent from the active manifest are skipped
//! with a note, so `cargo bench --benches` passes on a bare CPU).

use rigl::backend::{manifest_for, BackendKind};
use rigl::flops::{train_flops_per_sample, train_flops_ratio};
use rigl::prune::PruneSchedule;
use rigl::sparsity::{layer_sparsities, Distribution};
use rigl::topology::Method;
use rigl::util::{bench, smoke_mode};

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    let manifest = manifest_for(BackendKind::Native)?;
    println!(
        "== bench_flops: per-method accounting{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let reps = if smoke { 5 } else { 100 };
    for model in ["cnn", "wrn", "mlp"] {
        let Ok(def) = manifest.get(model) else {
            println!("(skipping {model}: not in the active manifest)");
            continue;
        };
        let s = layer_sparsities(def, 0.9, &Distribution::Erk);
        let sched = PruneSchedule::paper_default(32_000, s.clone());
        for m in [Method::Rigl, Method::Snfs, Method::Pruning] {
            bench(&format!("flops/{model}/{}", m.label()), reps, || {
                let _ = train_flops_per_sample(def, m, &s, 100, Some(&sched), 32_000);
            });
        }
        bench(&format!("flops_ratio/{model}"), reps, || {
            let _ = train_flops_ratio(def, Method::Rigl, &s, 100, None, 32_000, 5.0);
        });
    }
    Ok(())
}
