//! End-to-end train-step latency per model — the L3 hot path.
//!
//! One bench per paper track: these are the numbers behind every Fig-2/4
//! table cell, so the §Perf pass optimizes exactly what is measured here.
//!
//! Artifact-gated (PJRT): without a runtime or an AOT artifacts dir the
//! bench SKIPS cleanly (exit 0 with a note) instead of erroring, so
//! `cargo bench --benches -- --smoke` exercises every target on any
//! machine. `--smoke` shrinks the model list and rep counts to a CI-
//! sized probe (numbers not comparable across commits).

use rigl::model::load_manifest;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::util::{bench_to, smoke_mode, Rng};
use rigl::Runtime;

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    println!(
        "== bench_step: one optimizer step (exec + marshalling){} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping bench_step: no PJRT runtime: {e})");
            return Ok(());
        }
    };
    let manifest = match load_manifest(&rigl::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("(skipping bench_step: no artifacts manifest: {e})");
            return Ok(());
        }
    };
    let models: &[(&str, usize)] = if smoke {
        &[("mlp", 2)]
    } else {
        &[("mlp", 30), ("mlp_pallas", 30), ("cnn", 10), ("wrn", 5), ("mobilenet", 10), ("gru", 10)]
    };
    for &(model, iters) in models {
        let mut cfg = TrainConfig::new(model, Method::Rigl);
        cfg.sparsity = 0.9;
        cfg.data_train = if smoke { 64 } else { 256 };
        cfg.data_val = if smoke { 16 } else { 64 };
        // Per-model artifacts may be missing (partial `make artifacts`):
        // skip that model, keep benching the rest.
        let trainer = match Trainer::new(&rt, &manifest, &cfg) {
            Ok(t) => t,
            Err(e) => {
                println!("(skipping {model}: {e})");
                continue;
            }
        };
        let mut state = trainer.init_state(&cfg);
        let mut rng = Rng::new(1);
        let mut iter = trainer.batch_iter_pub(&cfg);
        let (x, y) = trainer.next_batch(&cfg, &mut iter, &mut rng);
        bench_to("step", &format!("train_step/{model}"), iters, || {
            trainer.sgd_step(&mut state, &x, &y, 0.01).unwrap();
        });
        bench_to("step", &format!("dense_grad/{model}"), iters.div_ceil(2), || {
            trainer.dense_grads(&state, &x, &y).unwrap();
        });
        bench_to("step", &format!("eval_batch/{model}"), iters, || {
            trainer.evaluate(&state, &cfg).unwrap();
        });
    }
    Ok(())
}
