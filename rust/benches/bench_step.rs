//! End-to-end train-step latency per model — the L3 hot path.
//!
//! One bench per paper track: these are the numbers behind every Fig-2/4
//! table cell, so the §Perf pass optimizes exactly what is measured here.

use rigl::model::load_manifest;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::util::{bench_to, Rng};
use rigl::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = load_manifest(&rigl::artifacts_dir())?;
    println!("== bench_step: one optimizer step (exec + marshalling) ==");
    for (model, iters) in [
        ("mlp", 30),
        ("mlp_pallas", 30),
        ("cnn", 10),
        ("wrn", 5),
        ("mobilenet", 10),
        ("gru", 10),
    ] {
        let mut cfg = TrainConfig::new(model, Method::Rigl);
        cfg.sparsity = 0.9;
        cfg.data_train = 256;
        cfg.data_val = 64;
        let trainer = Trainer::new(&rt, &manifest, &cfg)?;
        let mut state = trainer.init_state(&cfg);
        let mut rng = Rng::new(1);
        let mut iter = trainer.batch_iter_pub(&cfg);
        let (x, y) = trainer.next_batch(&cfg, &mut iter, &mut rng);
        bench_to("step", &format!("train_step/{model}"), iters, || {
            trainer.sgd_step(&mut state, &x, &y, 0.01).unwrap();
        });
        bench_to("step", &format!("dense_grad/{model}"), iters.div_ceil(2), || {
            trainer.dense_grads(&state, &x, &y).unwrap();
        });
        bench_to("step", &format!("eval_batch/{model}"), iters, || {
            trainer.evaluate(&state, &cfg).unwrap();
        });
    }
    Ok(())
}
