//! PJRT marshalling + execution overhead: where the request-path time goes.
//!
//! Separates literal construction, execution, and result read-back so the
//! §Perf pass can attribute the per-step cost (EXPERIMENTS.md §Perf).

use rigl::model::{load_manifest, ParamSet};
use rigl::runtime::{lit_f32, lit_i32};
use rigl::util::{bench, Rng};
use rigl::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = load_manifest(&rigl::artifacts_dir())?;
    println!("== bench_runtime: PJRT marshalling vs execution ==");

    for model in ["mlp", "cnn"] {
        let def = manifest.get(model)?;
        let exe = rt.load(&manifest.artifact_path(model, "eval")?)?;
        let mut rng = Rng::new(0);
        let params = ParamSet::init(def, &mut rng);
        let masks = ParamSet::ones(def);
        let b = def.batch_size();
        let x = vec![0.5f32; def.input_shape.iter().product()];
        let y = vec![0i32; b];
        let xdims: Vec<i64> = def.input_shape.iter().map(|&d| d as i64).collect();

        // 1. Literal construction alone (host→device copies).
        bench(&format!("marshal_inputs/{model}"), 50, || {
            let mut inputs = Vec::new();
            for (t, s) in params.tensors.iter().zip(&def.specs) {
                inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
            }
            for (t, s) in masks.tensors.iter().zip(&def.specs) {
                inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
            }
            inputs.push(lit_f32(&x, &xdims).unwrap());
            inputs.push(lit_i32(&y, &[b as i64]).unwrap());
            std::hint::black_box(inputs);
        });

        // 2. Full execute (marshal + run + read back).
        let mut inputs = Vec::new();
        for (t, s) in params.tensors.iter().zip(&def.specs) {
            inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
        }
        for (t, s) in masks.tensors.iter().zip(&def.specs) {
            inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
        }
        inputs.push(lit_f32(&x, &xdims).unwrap());
        inputs.push(lit_i32(&y, &[b as i64]).unwrap());
        bench(&format!("execute_eval/{model}"), 30, || {
            let _ = exe.run_f32(&inputs).unwrap();
        });
    }
    Ok(())
}
