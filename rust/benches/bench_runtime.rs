//! PJRT marshalling + execution overhead: where the request-path time goes.
//!
//! Separates literal construction, execution, and result read-back so the
//! §Perf pass can attribute the per-step cost (EXPERIMENTS.md §Perf).
//!
//! Artifact-gated (PJRT): without a runtime or an AOT artifacts dir the
//! bench SKIPS cleanly (exit 0 with a note) instead of erroring, so
//! `cargo bench --benches -- --smoke` exercises every target on any
//! machine. `--smoke` shrinks the model list and rep counts.

use rigl::model::{load_manifest, ParamSet};
use rigl::runtime::{lit_f32, lit_i32};
use rigl::util::{bench, smoke_mode, Rng};
use rigl::Runtime;

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    println!(
        "== bench_runtime: PJRT marshalling vs execution{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping bench_runtime: no PJRT runtime: {e})");
            return Ok(());
        }
    };
    let manifest = match load_manifest(&rigl::artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("(skipping bench_runtime: no artifacts manifest: {e})");
            return Ok(());
        }
    };
    let models: &[&str] = if smoke { &["mlp"] } else { &["mlp", "cnn"] };
    let (marshal_iters, exec_iters) = if smoke { (3, 2) } else { (50, 30) };

    for &model in models {
        // Per-model artifacts may be missing: skip that model cleanly.
        let (def, exe) = match manifest.get(model).and_then(|def| {
            let path = manifest.artifact_path(model, "eval")?;
            Ok((def, rt.load(&path)?))
        }) {
            Ok(pair) => pair,
            Err(e) => {
                println!("(skipping {model}: {e})");
                continue;
            }
        };
        let mut rng = Rng::new(0);
        let params = ParamSet::init(def, &mut rng);
        let masks = ParamSet::ones(def);
        let b = def.batch_size();
        let x = vec![0.5f32; def.input_shape.iter().product()];
        let y = vec![0i32; b];
        let xdims: Vec<i64> = def.input_shape.iter().map(|&d| d as i64).collect();

        // 1. Literal construction alone (host→device copies).
        bench(&format!("marshal_inputs/{model}"), marshal_iters, || {
            let mut inputs = Vec::new();
            for (t, s) in params.tensors.iter().zip(&def.specs) {
                inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
            }
            for (t, s) in masks.tensors.iter().zip(&def.specs) {
                inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
            }
            inputs.push(lit_f32(&x, &xdims).unwrap());
            inputs.push(lit_i32(&y, &[b as i64]).unwrap());
            std::hint::black_box(inputs);
        });

        // 2. Full execute (marshal + run + read back).
        let mut inputs = Vec::new();
        for (t, s) in params.tensors.iter().zip(&def.specs) {
            inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
        }
        for (t, s) in masks.tensors.iter().zip(&def.specs) {
            inputs.push(lit_f32(t, &s.dims_i64()).unwrap());
        }
        inputs.push(lit_f32(&x, &xdims).unwrap());
        inputs.push(lit_i32(&y, &[b as i64]).unwrap());
        bench(&format!("execute_eval/{model}"), exec_iters, || {
            let _ = exe.run_f32(&inputs).unwrap();
        });
    }
    Ok(())
}
