//! Sparsity-distribution solve + random mask init latency.

use rigl::model::load_manifest;
use rigl::sparsity::{layer_sparsities, random_masks, Distribution};
use rigl::util::{bench, Rng};

fn main() -> anyhow::Result<()> {
    let manifest = load_manifest(&rigl::artifacts_dir())?;
    println!("== bench_masks: distribution solve + random init ==");
    for model in ["mlp", "cnn", "wrn", "gru"] {
        let def = manifest.get(model)?;
        for (label, dist) in [
            ("uniform", Distribution::Uniform),
            ("erk", Distribution::Erk),
        ] {
            bench(&format!("solve/{model}/{label}"), 50, || {
                let _ = layer_sparsities(def, 0.9, &dist);
            });
        }
        let s = layer_sparsities(def, 0.9, &Distribution::Erk);
        let mut rng = Rng::new(3);
        bench(&format!("random_masks/{model}"), 20, || {
            let _ = random_masks(def, &s, &mut rng);
        });
    }
    Ok(())
}
