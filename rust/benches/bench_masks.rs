//! Sparsity-distribution solve + random mask init latency.
//!
//! Hermetic: uses the artifacts manifest when present, else the builtin
//! native model zoo (models absent from the active manifest are skipped
//! with a note, so `cargo bench --benches` passes on a bare CPU).

use rigl::backend::{manifest_for, BackendKind};
use rigl::sparsity::{layer_sparsities, random_masks, Distribution};
use rigl::util::{bench, smoke_mode, Rng};

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    let manifest = manifest_for(BackendKind::Native)?;
    println!(
        "== bench_masks: distribution solve + random init{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let (solve_reps, mask_reps) = if smoke { (3, 2) } else { (50, 20) };
    for model in ["mlp", "cnn", "wrn", "gru"] {
        let Ok(def) = manifest.get(model) else {
            println!("(skipping {model}: not in the active manifest)");
            continue;
        };
        for (label, dist) in [
            ("uniform", Distribution::Uniform),
            ("erk", Distribution::Erk),
        ] {
            bench(&format!("solve/{model}/{label}"), solve_reps, || {
                let _ = layer_sparsities(def, 0.9, &dist);
            });
        }
        let s = layer_sparsities(def, 0.9, &Distribution::Erk);
        let mut rng = Rng::new(3);
        bench(&format!("random_masks/{model}"), mask_reps, || {
            let _ = random_masks(def, &s, &mut rng);
        });
    }
    Ok(())
}
