//! Serving performance → `BENCH_serve.json`: inference latency vs
//! sparsity × threads (cost ∝ nnz, the paper's motivating claim,
//! measured at the serving layer) and micro-batched throughput vs
//! batch=1 at the same worker count.
//!
//! Record families in `BENCH_serve.json`:
//!
//! * `engine/forward/b=*/S=*/t=*/lanes=*` — in-process latency through
//!   the frozen CSR engine ([`util::BenchRecord`] shape, plus an
//!   effective-GFLOP/s field: 2·nnz·batch useful FLOPs per forward),
//!   over batch {1, 8} × sparsity × kernel threads × lane width
//!   (lanes sweep {1, 8} at b=8 only — a one-row batch has no panel,
//!   so b=1 records a single truthful `lanes=1` leg). `b=8, lanes=8`
//!   is the batch-panel SIMD path (one CSR walk feeding all eight rows
//!   — the micro-batcher's fused-forward shape); `lanes=1` forces the
//!   scalar loops. Mean time must DECREASE as
//!   sparsity rises; logits of every cell are verified BIT-identical to
//!   `t=1, lanes=1` (exit 1 on divergence).
//! * `engine/steady_state_allocs/b=*/S=*/t=*/lanes=*` — heap
//!   allocations per request on a warm engine, counted by the global
//!   allocator WITH the kernel pool and the panel scratch engaged; any
//!   nonzero value is a regression and the binary exits 1 (same
//!   discipline as bench_topology).
//! * `artifact/bytes/S=0.9/{v1,v2+f32,v2+f16}` — on-disk artifact size
//!   of the three export formats on the same S=0.9 model. GATED: v2+f16
//!   must be ≥40% smaller than v1 (the headline compression claim) and
//!   v2+f32 ≥25% smaller, else exit 1.
//! * `engine/forward_packed/b=*/S=*/t=*/fmt=*` — decode-on-the-fly
//!   latency through packed (RIGLSRVD v2) weights, same GFLOP/s field.
//!   GATED: `fmt=v2+f32` logits bit-identical to the plain engine at
//!   every cell; `fmt=v2+f16` within an epsilon bound with margin-gated
//!   top-1 agreement; and the packed decode path passes the same
//!   steady-state zero-allocation gate (warm `PanelScratch` staging).
//! * `tcp/*` — end-to-end loopback numbers from the load generator:
//!   `tcp/single/S=*` for per-request latency vs sparsity,
//!   `tcp/batched-vs-serial/*` for the coalescing win — micro-batched
//!   throughput (`max_batch` 32) must exceed batch=1 throughput at the
//!   SAME worker count under concurrent load — and `tcp/overload/*`
//!   for admission-control behavior: a starved 1-worker/1-deep-queue
//!   server under a wide flood, once with a bare client (raw shed
//!   rate, `busy` field) and once with seeded retry/backoff (sheds
//!   converted into bounded-latency completions).
//! * `tcp/sharded/shards=*/c=*` — the accept-shard scaling axis: the
//!   SAME per-shard resources (workers, queue) at shards {1, 2} under a
//!   wide (c ≥ 256 full-mode) flood with retry/backoff. The headline
//!   gate: 2 shards should deliver ≥1.5× the 1-shard rps at saturating
//!   concurrency (printed and flagged as a WARNING, not an exit —
//!   core-count on the runner legitimately caps the win) with p99
//!   bounded under overload.
//! * `tcp/client-batch/R=*/c=*` — client-side batching via multi-row
//!   INFERM frames: R rows per frame against the sharded server;
//!   `requests`/`rps` count rows, latency percentiles are per-frame.
//!
//! Hermetic: no artifacts, no PJRT, models are built in code
//! (`cargo bench --bench bench_serve`; `-- --smoke` for the CI
//! variant).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use rigl::backend::native::kernels::set_panel_kernels;
use rigl::backend::native::mlp_def;
use rigl::pool::KernelPool;
use rigl::serve::{
    run_load, run_load_opts, top_k, InferEngine, LoadOpts, RetryPolicy, ServeConfig, Server,
    SparseModel, TopKScratch,
};
use rigl::sparsity::Distribution;
use rigl::util::{append_bench_json, bench_to_flops, smoke_mode, Rng};

/// Forwarding allocator that counts allocation events (alloc + realloc).
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn model_at(sparsity: f64) -> SparseModel {
    let def = mlp_def("bench_serve_mlp", 784, &[512, 256], 10, 1);
    SparseModel::init_random(&def, sparsity, &Distribution::Uniform, 0xBE).unwrap()
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    println!(
        "== bench_serve: frozen-CSR inference latency + micro-batch throughput{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let sparsities: &[f64] = if smoke { &[0.9] } else { &[0.98, 0.9, 0.5, 0.0] };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let fwd_iters = if smoke { 20 } else { 300 };
    let mut failed = false;

    // ---- engine-only: latency vs batch × sparsity × threads × lanes,
    // ---- bit-identity, and the zero-alloc gate with the pool and the
    // ---- panel scratch engaged ---------------------------------------
    let batches: &[usize] = &[1, 8];
    let mut engine_means = Vec::new();
    for &s in sparsities {
        let model = model_at(s);
        let nnz: usize = model.layers.iter().map(|l| l.topo.nnz()).sum();
        let mut rng = Rng::new(1);
        for &b in batches {
            // Panels need a full 8-row batch; at b=1 a lanes=8 leg would
            // re-measure the scalar path under a misleading label.
            let lane_widths: &[usize] = if b >= 8 { &[1, 8] } else { &[1] };
            let x: Vec<f32> = (0..b * 784).map(|_| rng.next_f32()).collect();
            let mut baseline: Vec<u32> = Vec::new();
            for &t in thread_counts {
                for &lanes in lane_widths {
                    let was = set_panel_kernels(lanes > 1);
                    // Pool + engine built BEFORE the warm window: their
                    // setup allocations are not steady-state. The floor
                    // is pinned to 1 so the bit-identity and zero-alloc
                    // gates genuinely exercise the pooled paths even on
                    // a runner whose measured floor exceeds the layers.
                    let pool = (t > 1).then(|| Arc::new(KernelPool::with_par_min_ops(t, 1)));
                    let mut eng = InferEngine::new(&model, b);
                    eng.set_pool(pool);
                    let mut scratch = TopKScratch::default();
                    let mut pairs = Vec::new();
                    let flops = 2.0 * nnz as f64 * b as f64;
                    let mean = bench_to_flops(
                        "serve",
                        &format!("engine/forward/b={b}/S={s}/t={t}/lanes={lanes}"),
                        fwd_iters,
                        Some(flops),
                        || {
                            let logits = eng.forward(&model, &x, b);
                            top_k(&logits[..model.classes()], 1, &mut scratch, &mut pairs);
                        },
                    );
                    if t == 1 && lanes == 1 && b == 1 {
                        engine_means.push((s, mean));
                    }
                    let got: Vec<u32> =
                        eng.forward(&model, &x, b).iter().map(|v| v.to_bits()).collect();
                    if t == 1 && lanes == 1 {
                        baseline = got;
                    } else if got != baseline {
                        failed = true;
                        eprintln!(
                            "REGRESSION: b={b} S={s} t={t} lanes={lanes} logits diverged \
                             from t=1 lanes=1"
                        );
                    }

                    // Warm from the bench above: further requests must
                    // not allocate — including every fork-join dispatch
                    // and every panel transpose.
                    let iters = if smoke { 20u64 } else { 100 };
                    let before = ALLOC_EVENTS.load(Ordering::Relaxed);
                    for _ in 0..iters {
                        let logits = eng.forward(&model, &x, b);
                        top_k(&logits[..model.classes()], 1, &mut scratch, &mut pairs);
                    }
                    let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
                    let per_req = allocs as f64 / iters as f64;
                    println!(
                        "engine/steady_state_allocs/b={b}/S={s}/t={t}/lanes={lanes}  \
                         {per_req:.2} allocs/request"
                    );
                    append_bench_json(
                        "serve",
                        &format!(
                            "{{\"name\":\"engine/steady_state_allocs/b={b}/S={s}/t={t}/lanes={lanes}\",\"iters\":{iters},\
                             \"mean_s\":{per_req:.9},\"min_s\":{per_req:.9},\"git_rev\":\"{}\",\"unix_ms\":{}}}",
                            rigl::util::git_rev(),
                            rigl::util::unix_ms()
                        ),
                    )?;
                    if allocs != 0 {
                        failed = true;
                        eprintln!(
                            "REGRESSION: {allocs} heap allocations over {iters} warm \
                             requests (b={b} S={s} t={t} lanes={lanes})"
                        );
                    }
                    set_panel_kernels(was);
                }
            }
        }
    }
    if let (Some(sparse), Some(dense)) = (
        engine_means.iter().find(|m| m.0 == 0.9),
        engine_means.iter().find(|m| m.0 == 0.0),
    ) {
        println!(
            "engine latency ratio dense/S=0.9 (b=1 t=1): {:.2}x (cost ∝ nnz ⇒ should \
             approach the sparsifiable share)",
            dense.1 / sparse.1
        );
    }

    // ---- packed (RIGLSRVD v2) artifacts: compression ratio + decode-
    // ---- on-the-fly latency, bit-identity / epsilon / alloc gates ----
    {
        use rigl::serve::ValueKind;
        let s = 0.9;
        let model = model_at(s);
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let p1 = dir.join(format!("bench_serve_{pid}_v1.srvd"));
        let p2 = dir.join(format!("bench_serve_{pid}_v2f32.srvd"));
        let p3 = dir.join(format!("bench_serve_{pid}_v2f16.srvd"));
        model.save(&p1)?;
        model.save_v2(&p2, ValueKind::F32)?;
        model.save_v2(&p3, ValueKind::F16)?;
        let len = |p: &std::path::Path| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
        let (b1, b2, b3) = (len(&p1), len(&p2), len(&p3));
        for (label, bytes) in [("v1", b1), ("v2+f32", b2), ("v2+f16", b3)] {
            println!("artifact/bytes/S={s}/{label}  {bytes} bytes");
            append_bench_json(
                "serve",
                &format!(
                    "{{\"name\":\"artifact/bytes/S={s}/{label}\",\"iters\":1,\
                     \"mean_s\":{bytes},\"min_s\":{bytes},\"git_rev\":\"{}\",\"unix_ms\":{}}}",
                    rigl::util::git_rev(),
                    rigl::util::unix_ms()
                ),
            )?;
        }
        if (b2 as f64) > 0.75 * b1 as f64 {
            failed = true;
            eprintln!("REGRESSION: v2+f32 artifact {b2} bytes is not ≥25% smaller than v1 {b1}");
        }
        if (b3 as f64) > 0.60 * b1 as f64 {
            failed = true;
            eprintln!("REGRESSION: v2+f16 artifact {b3} bytes is not ≥40% smaller than v1 {b1}");
        }
        let packed32 = SparseModel::load(&p2)?;
        let packed16 = SparseModel::load(&p3)?;
        for p in [&p1, &p2, &p3] {
            std::fs::remove_file(p).ok();
        }
        let nnz: usize = model.nnz();
        let mut rng = Rng::new(2);
        for &b in batches {
            let x: Vec<f32> = (0..b * 784).map(|_| rng.next_f32()).collect();
            let mut base_eng = InferEngine::new(&model, b);
            let base: Vec<f32> = base_eng.forward(&model, &x, b).to_vec();
            let base_bits: Vec<u32> = base.iter().map(|v| v.to_bits()).collect();
            let scale = base.iter().fold(1.0f32, |m, v| m.max(v.abs()));
            let eps = 0.02 * scale;
            for &t in thread_counts {
                for (fmt, pm) in [("v2+f32", &packed32), ("v2+f16", &packed16)] {
                    let pool = (t > 1).then(|| Arc::new(KernelPool::with_par_min_ops(t, 1)));
                    let mut eng = InferEngine::new(pm, b);
                    eng.set_pool(pool);
                    let mut scratch = TopKScratch::default();
                    let mut pairs = Vec::new();
                    let flops = 2.0 * nnz as f64 * b as f64;
                    bench_to_flops(
                        "serve",
                        &format!("engine/forward_packed/b={b}/S={s}/t={t}/fmt={fmt}"),
                        fwd_iters,
                        Some(flops),
                        || {
                            let logits = eng.forward(pm, &x, b);
                            top_k(&logits[..pm.classes()], 1, &mut scratch, &mut pairs);
                        },
                    );
                    let got: Vec<f32> = eng.forward(pm, &x, b).to_vec();
                    if fmt == "v2+f32" {
                        let bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                        if bits != base_bits {
                            failed = true;
                            eprintln!(
                                "REGRESSION: packed f32 logits diverged from plain \
                                 (b={b} t={t})"
                            );
                        }
                    } else {
                        // f16: epsilon bound + margin-gated top-1 agreement
                        // (near-ties may legitimately flip).
                        let classes = pm.classes();
                        for (bi, (a, e)) in got.iter().zip(&base).enumerate() {
                            if (a - e).abs() > eps {
                                failed = true;
                                eprintln!(
                                    "REGRESSION: f16 logit {a} vs {e} exceeds eps {eps} \
                                     (b={b} t={t} idx={bi})"
                                );
                                break;
                            }
                        }
                        for bi in 0..b {
                            let row = &base[bi * classes..(bi + 1) * classes];
                            let grow = &got[bi * classes..(bi + 1) * classes];
                            let top = |r: &[f32]| {
                                (0..r.len())
                                    .max_by(|&i, &j| r[i].partial_cmp(&r[j]).unwrap())
                                    .unwrap()
                            };
                            let (w1, g1) = (top(row), top(grow));
                            let mut second = f32::NEG_INFINITY;
                            for (c, &v) in row.iter().enumerate() {
                                if c != w1 && v > second {
                                    second = v;
                                }
                            }
                            if row[w1] - second > 2.0 * eps && g1 != w1 {
                                failed = true;
                                eprintln!(
                                    "REGRESSION: f16 top-1 flipped on a confident row \
                                     (b={b} t={t} row={bi})"
                                );
                            }
                        }
                    }
                    // Warm from the bench above: the decode staging must
                    // be steady-state allocation-free like everything else.
                    let iters = if smoke { 20u64 } else { 100 };
                    let before = ALLOC_EVENTS.load(Ordering::Relaxed);
                    for _ in 0..iters {
                        let logits = eng.forward(pm, &x, b);
                        top_k(&logits[..pm.classes()], 1, &mut scratch, &mut pairs);
                    }
                    let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
                    let per_req = allocs as f64 / iters as f64;
                    println!(
                        "engine/steady_state_allocs/b={b}/S={s}/t={t}/fmt={fmt}  \
                         {per_req:.2} allocs/request"
                    );
                    append_bench_json(
                        "serve",
                        &format!(
                            "{{\"name\":\"engine/steady_state_allocs/b={b}/S={s}/t={t}/fmt={fmt}\",\"iters\":{iters},\
                             \"mean_s\":{per_req:.9},\"min_s\":{per_req:.9},\"git_rev\":\"{}\",\"unix_ms\":{}}}",
                            rigl::util::git_rev(),
                            rigl::util::unix_ms()
                        ),
                    )?;
                    if allocs != 0 {
                        failed = true;
                        eprintln!(
                            "REGRESSION: {allocs} heap allocations over {iters} warm \
                             packed requests (b={b} S={s} t={t} fmt={fmt})"
                        );
                    }
                }
            }
        }
    }

    // ---- TCP end to end: single-request latency vs sparsity ----------
    let tcp_requests = if smoke { 20 } else { 300 };
    for &s in sparsities {
        let server = Server::start(
            model_at(s),
            None,
            ServeConfig {
                workers: 1,
                max_batch: 1,
                max_wait_us: 0,
                ..ServeConfig::default()
            },
        )?;
        let stats = run_load(&server.addr().to_string(), 1, tcp_requests, 1)?;
        println!("tcp/single/S={s}: {}", stats.render());
        append_bench_json("serve", &stats.to_json(&format!("tcp/single/S={s}")))?;
        server.shutdown();
    }

    // ---- micro-batching: throughput at fixed worker count ------------
    let concurrency = if smoke { 4 } else { 16 };
    let requests = if smoke { 20 } else { 200 };
    let mut rps = Vec::new();
    for &(label, max_batch, max_wait_us) in
        &[("serial/b=1", 1usize, 0u64), ("batched/b=32", 32, 300)]
    {
        let server = Server::start(
            model_at(0.9),
            None,
            ServeConfig {
                workers: 2,
                max_batch,
                max_wait_us,
                ..ServeConfig::default()
            },
        )?;
        let stats = run_load(&server.addr().to_string(), concurrency, requests, 1)?;
        let (reqs, batches) = server.stats();
        println!(
            "tcp/batched-vs-serial/{label}: {} ({reqs} requests in {batches} batches)",
            stats.render()
        );
        if let Some(line) = stats.render_server() {
            println!("tcp/batched-vs-serial/{label}: {line}");
        }
        append_bench_json(
            "serve",
            &stats.to_json(&format!("tcp/batched-vs-serial/{label}/c={concurrency}")),
        )?;
        rps.push(stats.rps);
        server.shutdown();
    }
    if rps.len() == 2 {
        println!(
            "micro-batch throughput gain at 2 workers, c={concurrency}: {:.2}x",
            rps[1] / rps[0]
        );
    }

    // ---- overload: a deliberately starved server (1 worker, 1-deep
    // ---- queue) under a wide flood. `raw` measures the shed rate a
    // ---- retry-less client sees; `retry` shows seeded backoff
    // ---- converting sheds into bounded-latency completions. Sheds are
    // ---- the server *working* — the gate is only that accepted
    // ---- requests complete and the run never wedges.
    let over_conc = if smoke { 8 } else { 32 };
    let over_reqs = if smoke { 10 } else { 100 };
    for &(label, retry) in &[
        ("raw", None),
        (
            "retry",
            Some(RetryPolicy {
                attempts: 5,
                base: std::time::Duration::from_millis(1),
                max: std::time::Duration::from_millis(20),
                seed: 0x0E11,
            }),
        ),
    ] {
        let server = Server::start(
            model_at(0.9),
            None,
            ServeConfig {
                workers: 1,
                max_batch: 4,
                max_wait_us: 0,
                queue_depth: 1,
                ..ServeConfig::default()
            },
        )?;
        let stats = run_load_opts(
            &server.addr().to_string(),
            over_conc,
            over_reqs,
            1,
            LoadOpts {
                deadline_ms: 2_000,
                retry,
                timeout: Some(std::time::Duration::from_secs(30)),
                client_batch: 1,
            },
        )?;
        let shed_total = server.info_stats().shed;
        println!(
            "tcp/overload/{label}/c={over_conc}: {} (server shed {shed_total} total)",
            stats.render()
        );
        if let Some(line) = stats.render_server() {
            println!("tcp/overload/{label}/c={over_conc}: {line}");
        }
        append_bench_json("serve", &stats.to_json(&format!("tcp/overload/{label}/c={over_conc}")))?;
        server.shutdown();
    }

    // ---- accept-shard scaling: identical per-shard resources at
    // ---- shards {1, 2} under a saturating flood. The event loops (not
    // ---- the engines) are the variable: rps should scale toward the
    // ---- shard count until cores run out. Flagged as a WARNING rather
    // ---- than an exit — a 2-core runner cannot double anything.
    let shard_conc = if smoke { 64 } else { 256 };
    let shard_reqs = if smoke { 5 } else { 50 };
    let retry = RetryPolicy {
        attempts: 5,
        base: std::time::Duration::from_millis(1),
        max: std::time::Duration::from_millis(20),
        seed: 0x54A2D,
    };
    let mut shard_rps = Vec::new();
    for shards in [1usize, 2] {
        let server = Server::start(
            model_at(0.9),
            None,
            ServeConfig {
                shards,
                workers: 2,
                max_batch: 8,
                max_wait_us: 100,
                queue_depth: 64, // per shard
                max_conns: shard_conc * 2,
                ..ServeConfig::default()
            },
        )?;
        let stats = run_load_opts(
            &server.addr().to_string(),
            shard_conc,
            shard_reqs,
            1,
            LoadOpts {
                deadline_ms: 5_000,
                retry: Some(retry),
                timeout: Some(std::time::Duration::from_secs(30)),
                client_batch: 1,
            },
        )?;
        println!("tcp/sharded/shards={shards}/c={shard_conc}: {}", stats.render());
        if let Some(line) = stats.render_server() {
            println!("tcp/sharded/shards={shards}/c={shard_conc}: {line}");
        }
        append_bench_json(
            "serve",
            &stats.to_json(&format!("tcp/sharded/shards={shards}/c={shard_conc}")),
        )?;
        // p99 must stay bounded under overload: the deadline + retry
        // budget cap any accepted request's latency.
        if stats.p99_us > 30_000_000.0 {
            failed = true;
            eprintln!(
                "REGRESSION: shards={shards} p99 {}µs breached the 30s bound under overload",
                stats.p99_us
            );
        }
        shard_rps.push(stats.rps);
        server.shutdown();
    }
    if shard_rps.len() == 2 {
        let gain = shard_rps[1] / shard_rps[0].max(1e-12);
        println!(
            "shard scaling at c={shard_conc}: 2 shards = {gain:.2}x of 1 shard \
             (target ≥1.50x on a ≥4-core runner)"
        );
        if gain < 1.5 {
            eprintln!(
                "WARNING: shard scaling {gain:.2}x < 1.50x — expected on few-core \
                 runners; investigate if cores ≥ 4"
            );
        }
    }

    // ---- client-side batching: R rows per multi-row INFERM frame
    // ---- against the sharded server. rps counts ROWS, so the win is
    // ---- framing + syscall amortization on top of server coalescing.
    let cb_conc = if smoke { 4 } else { 16 };
    let cb_reqs = if smoke { 10 } else { 100 };
    let mut cb_rps = Vec::new();
    for r in [1usize, 8] {
        let server = Server::start(
            model_at(0.9),
            None,
            ServeConfig {
                shards: 2,
                workers: 2,
                max_batch: 16,
                max_wait_us: 100,
                ..ServeConfig::default()
            },
        )?;
        let stats = run_load_opts(
            &server.addr().to_string(),
            cb_conc,
            cb_reqs,
            1,
            LoadOpts {
                deadline_ms: 5_000,
                retry: None,
                timeout: Some(std::time::Duration::from_secs(30)),
                client_batch: r,
            },
        )?;
        println!("tcp/client-batch/R={r}/c={cb_conc}: {}", stats.render());
        append_bench_json("serve", &stats.to_json(&format!("tcp/client-batch/R={r}/c={cb_conc}")))?;
        cb_rps.push(stats.rps);
        server.shutdown();
    }
    if cb_rps.len() == 2 {
        println!(
            "client-batch row-throughput gain R=8 vs R=1 at c={cb_conc}: {:.2}x",
            cb_rps[1] / cb_rps[0].max(1e-12)
        );
    }

    if failed {
        std::process::exit(1);
    }
    Ok(())
}
