//! Serving performance → `BENCH_serve.json`: inference latency vs
//! sparsity (cost ∝ nnz, the paper's motivating claim, measured at the
//! serving layer) and micro-batched throughput vs batch=1 at the same
//! worker count.
//!
//! Three record families land in `BENCH_serve.json`:
//!
//! * `engine/forward/b=1/S=*` — in-process single-row latency through
//!   the frozen CSR engine ([`util::BenchRecord`] shape). Mean time
//!   must DECREASE as sparsity increases.
//! * `engine/steady_state_allocs/S=*` — heap allocations per request on
//!   a warm engine, counted by the global allocator; any nonzero value
//!   is a regression and the binary exits 1 (same discipline as
//!   bench_topology).
//! * `tcp/*` — end-to-end loopback numbers from the load generator
//!   (`{requests, wall_s, rps, mean_us, p50_us, p99_us}`):
//!   `tcp/single/S=*` for per-request latency vs sparsity and
//!   `tcp/batched-vs-serial/*` for the coalescing win — micro-batched
//!   throughput (`max_batch` 32) must exceed batch=1 throughput at the
//!   SAME worker count under concurrent load.
//!
//! Hermetic: no artifacts, no PJRT, models are built in code
//! (`cargo bench --bench bench_serve`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use rigl::backend::native::mlp_def;
use rigl::serve::{run_load, top_k, InferEngine, ServeConfig, Server, SparseModel, TopKScratch};
use rigl::sparsity::Distribution;
use rigl::util::{append_bench_json, bench_to, Rng};

/// Forwarding allocator that counts allocation events (alloc + realloc).
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn model_at(sparsity: f64) -> SparseModel {
    let def = mlp_def("bench_serve_mlp", 784, &[512, 256], 10, 1);
    SparseModel::init_random(&def, sparsity, &Distribution::Uniform, 0xBE).unwrap()
}

fn main() -> anyhow::Result<()> {
    println!("== bench_serve: frozen-CSR inference latency + micro-batch throughput ==");
    let sparsities = [0.98f64, 0.9, 0.5, 0.0];

    // ---- engine-only: single-row latency vs sparsity + zero-alloc ----
    let mut engine_means = Vec::new();
    for &s in &sparsities {
        let model = model_at(s);
        let mut eng = InferEngine::new(&model, 1);
        let mut scratch = TopKScratch::default();
        let mut pairs = Vec::new();
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
        let mean = bench_to("serve", &format!("engine/forward/b=1/S={s}"), 300, || {
            let logits = eng.forward(&model, &x, 1);
            top_k(logits, 1, &mut scratch, &mut pairs);
        });
        engine_means.push((s, mean));

        // Warm from the bench above: further requests must not allocate.
        let iters = 100u64;
        let before = ALLOC_EVENTS.load(Ordering::Relaxed);
        for _ in 0..iters {
            let logits = eng.forward(&model, &x, 1);
            top_k(logits, 1, &mut scratch, &mut pairs);
        }
        let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
        let per_req = allocs as f64 / iters as f64;
        println!("engine/steady_state_allocs/S={s}             {per_req:.2} allocs/request");
        append_bench_json(
            "serve",
            &format!(
                "{{\"name\":\"engine/steady_state_allocs/S={s}\",\"iters\":{iters},\
                 \"mean_s\":{per_req:.9},\"min_s\":{per_req:.9},\"git_rev\":\"{}\"}}",
                rigl::util::git_rev()
            ),
        )?;
        if allocs != 0 {
            eprintln!("REGRESSION: {allocs} heap allocations over {iters} warm requests (S={s})");
            std::process::exit(1);
        }
    }
    if let (Some(sparse), Some(dense)) = (
        engine_means.iter().find(|m| m.0 == 0.9),
        engine_means.iter().find(|m| m.0 == 0.0),
    ) {
        println!(
            "engine latency ratio dense/S=0.9: {:.2}x (cost ∝ nnz ⇒ should approach the \
             sparsifiable share)",
            dense.1 / sparse.1
        );
    }

    // ---- TCP end to end: single-request latency vs sparsity ----------
    for &s in &sparsities {
        let server = Server::start(
            model_at(s),
            None,
            ServeConfig {
                workers: 1,
                max_batch: 1,
                max_wait_us: 0,
                ..ServeConfig::default()
            },
        )?;
        let stats = run_load(&server.addr().to_string(), 1, 300, 1)?;
        println!("tcp/single/S={s}: {}", stats.render());
        append_bench_json("serve", &stats.to_json(&format!("tcp/single/S={s}")))?;
        server.shutdown();
    }

    // ---- micro-batching: throughput at fixed worker count ------------
    let concurrency = 16;
    let requests = 200;
    let mut rps = Vec::new();
    for &(label, max_batch, max_wait_us) in
        &[("serial/b=1", 1usize, 0u64), ("batched/b=32", 32, 300)]
    {
        let server = Server::start(
            model_at(0.9),
            None,
            ServeConfig {
                workers: 2,
                max_batch,
                max_wait_us,
                ..ServeConfig::default()
            },
        )?;
        let stats = run_load(&server.addr().to_string(), concurrency, requests, 1)?;
        let (reqs, batches) = server.stats();
        println!(
            "tcp/batched-vs-serial/{label}: {} ({reqs} requests in {batches} batches)",
            stats.render()
        );
        append_bench_json(
            "serve",
            &stats.to_json(&format!("tcp/batched-vs-serial/{label}/c={concurrency}")),
        )?;
        rps.push(stats.rps);
        server.shutdown();
    }
    if rps.len() == 2 {
        println!(
            "micro-batch throughput gain at 2 workers, c={concurrency}: {:.2}x",
            rps[1] / rps[0]
        );
    }
    Ok(())
}
