//! Drop/grow mask-update latency and allocation counts vs layer size —
//! the coordinator's own compute (top-k selection is O(n) via select_nth).
//!
//! Two paths are measured and recorded to `BENCH_topology.json`:
//!
//! * `fresh_scratch` — the allocating wrapper `update_masks`, which
//!   builds its working buffers per call (the seed's allocation
//!   pattern);
//! * `reused_scratch` — `update_masks_scratch` with a warm
//!   `TopoScratch`, the training-loop hot path.
//!
//! A counting global allocator verifies the headline property: the
//! reused-scratch path performs ZERO heap allocations per update in the
//! steady state. The binary exits non-zero if that regresses.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use rigl::model::{ElemType, Kind, ModelDef, Optimizer, ParamSet, ParamSpec, Task};
use rigl::obs::topo::TopoRecorder;
use rigl::topology::{
    update_masks, update_masks_scratch, update_masks_visit, Grow, TopoScratch, UpdateStats,
};
use rigl::util::{append_bench_record, bench_to, git_rev, smoke_mode, BenchRecord, Rng};

/// Forwarding allocator that counts allocation events (alloc + realloc).
struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn synth_def(n: usize) -> ModelDef {
    ModelDef {
        name: format!("synth{n}"),
        backend: "jnp".into(),
        optimizer: Optimizer::SgdMomentum,
        task: Task::Classify,
        input_ty: ElemType::F32,
        input_shape: vec![1, 1],
        target_shape: vec![1],
        hyper: vec![],
        artifacts: vec![],
        specs: vec![ParamSpec {
            name: "w".into(),
            kind: Kind::Fc,
            sparsifiable: true,
            first_layer: false,
            flops: 0.0,
            shape: vec![n, 1],
        }],
    }
}

fn setup(n: usize) -> (ModelDef, ParamSet, ParamSet, ParamSet, ParamSet) {
    let def = synth_def(n);
    let mut rng = Rng::new(0);
    let params = ParamSet::init(&def, &mut rng);
    let mut masks = ParamSet::zeros(&def);
    for i in 0..n / 10 {
        masks.tensors[0][i * 10] = 1.0; // 10% dense
    }
    let grads = ParamSet::init(&def, &mut rng);
    let mom = ParamSet::zeros(&def);
    (def, params, masks, grads, mom)
}

fn main() {
    let smoke = smoke_mode();
    println!(
        "== bench_topology: one Algorithm-1 mask update{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    // Smoke mode (CI): one small size, minimal reps — still exercises
    // the counting-allocator zero-alloc gate below.
    let sizes: &[usize] = if smoke {
        &[10_000]
    } else {
        &[10_000, 100_000, 1_000_000, 4_000_000]
    };
    let reps = if smoke { 2 } else { 10 };
    let mut steady_state_ok = true;
    for n in sizes.iter().copied() {
        // ------- fresh scratch (the seed's allocation pattern) -------
        let (def, mut params, mut masks, grads, mut mom) = setup(n);
        bench_to("topology", &format!("rigl_update/fresh_scratch/n={n}"), reps, || {
            update_masks(
                &def,
                &mut params,
                std::slice::from_mut(&mut mom),
                &mut masks,
                0.3,
                Grow::Gradient(&grads),
            );
        });

        // ------- reused scratch (the training-loop hot path) ---------
        let (def, mut params, mut masks, grads, mut mom) = setup(n);
        let mut scratch = TopoScratch::default();
        let mut stats = UpdateStats::default();
        bench_to("topology", &format!("rigl_update/reused_scratch/n={n}"), reps, || {
            update_masks_scratch(
                &def,
                &mut params,
                std::slice::from_mut(&mut mom),
                &mut masks,
                0.3,
                Grow::Gradient(&grads),
                &mut scratch,
                &mut stats,
            );
        });

        // Steady-state allocation count: buffers are warm after the
        // bench above, so further updates must not touch the heap.
        let updates = 5u64;
        let before = ALLOC_EVENTS.load(Ordering::Relaxed);
        for _ in 0..updates {
            update_masks_scratch(
                &def,
                &mut params,
                std::slice::from_mut(&mut mom),
                &mut masks,
                0.3,
                Grow::Gradient(&grads),
                &mut scratch,
                &mut stats,
            );
        }
        let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
        let per_update = allocs as f64 / updates as f64;
        println!("rigl_update/steady_state_allocs/n={n}      {per_update:.1} allocs/update");
        // Machine-readable: mean_s carries allocs-per-update for /allocs
        // records (documented in ROADMAP; the bench is about counts, not
        // time).
        let _ = append_bench_record(
            "topology",
            &BenchRecord {
                name: format!("rigl_update/steady_state_allocs/n={n}"),
                iters: updates as usize,
                mean_s: per_update,
                min_s: per_update,
                gflops: None,
                git_rev: git_rev(),
                unix_ms: rigl::util::unix_ms(),
            },
        );
        if allocs != 0 {
            steady_state_ok = false;
            eprintln!("REGRESSION: {allocs} heap allocations over {updates} warm updates (n={n})");
        }

        // ------- SET random grow, reused scratch ---------------------
        let (def, mut params, mut masks, _, mut mom) = setup(n);
        let mut rng2 = Rng::new(7);
        bench_to("topology", &format!("set_update/reused_scratch/n={n}"), reps, || {
            update_masks_scratch(
                &def,
                &mut params,
                std::slice::from_mut(&mut mom),
                &mut masks,
                0.3,
                Grow::Random(&mut rng2),
                &mut scratch,
                &mut stats,
            );
        });

        // ------- rest of the grow zoo, reused scratch ----------------
        // SNFS momentum grow scores like gradient grow (a dense score
        // tensor), magnitude grow reads the live weights — together with
        // the legs above, every GrowCriterion is timed on one axis.
        let (def, mut params, mut masks, grads, mut mom) = setup(n);
        bench_to("topology", &format!("snfs_update/reused_scratch/n={n}"), reps, || {
            update_masks_scratch(
                &def,
                &mut params,
                std::slice::from_mut(&mut mom),
                &mut masks,
                0.3,
                Grow::Momentum(&grads),
                &mut scratch,
                &mut stats,
            );
        });
        let (def, mut params, mut masks, _, mut mom) = setup(n);
        bench_to("topology", &format!("magnitude_update/reused_scratch/n={n}"), reps, || {
            update_masks_scratch(
                &def,
                &mut params,
                std::slice::from_mut(&mut mom),
                &mut masks,
                0.3,
                Grow::Magnitude,
                &mut scratch,
                &mut stats,
            );
        });

        // ------- topology recorder riding the visitor ----------------
        // The full observability path: drop/grow plus the obs::topo
        // recorder ingesting every (dropped, grown) list. Held to the
        // same zero-allocation standard as the bare update.
        let (def, mut params, mut masks, grads, mut mom) = setup(n);
        let mut rec = TopoRecorder::new(&def, &masks, reps * 4 + 64);
        let mut step = 0usize;
        let mut run_recorded = |rec: &mut TopoRecorder,
                                params: &mut ParamSet,
                                mom: &mut ParamSet,
                                masks: &mut ParamSet,
                                scratch: &mut TopoScratch,
                                stats: &mut UpdateStats,
                                step: &mut usize| {
            update_masks_visit(
                &def,
                params,
                std::slice::from_mut(mom),
                masks,
                0.3,
                Grow::Gradient(&grads),
                scratch,
                stats,
                |li, dropped, grown| rec.record_layer(li, dropped, grown),
            );
            *step += 1;
            rec.end_update(*step);
        };
        bench_to("topology", &format!("rigl_update/with_recorder/n={n}"), reps, || {
            run_recorded(
                &mut rec,
                &mut params,
                &mut mom,
                &mut masks,
                &mut scratch,
                &mut stats,
                &mut step,
            );
        });
        let before = ALLOC_EVENTS.load(Ordering::Relaxed);
        for _ in 0..updates {
            run_recorded(
                &mut rec,
                &mut params,
                &mut mom,
                &mut masks,
                &mut scratch,
                &mut stats,
                &mut step,
            );
        }
        let allocs = ALLOC_EVENTS.load(Ordering::Relaxed) - before;
        let per_update = allocs as f64 / updates as f64;
        println!("rigl_update/recorder_steady_allocs/n={n}  {per_update:.1} allocs/update");
        let _ = append_bench_record(
            "topology",
            &BenchRecord {
                name: format!("rigl_update/recorder_steady_allocs/n={n}"),
                iters: updates as usize,
                mean_s: per_update,
                min_s: per_update,
                gflops: None,
                git_rev: git_rev(),
                unix_ms: rigl::util::unix_ms(),
            },
        );
        if allocs != 0 {
            steady_state_ok = false;
            eprintln!(
                "REGRESSION: recorder path made {allocs} heap allocations over {updates} warm updates (n={n})"
            );
        }
    }
    if !steady_state_ok {
        std::process::exit(1);
    }
}
