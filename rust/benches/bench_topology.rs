//! Drop/grow mask-update latency vs layer size — the coordinator's own
//! compute (top-k selection is O(n) via select_nth).

use rigl::model::{ElemType, Kind, ModelDef, Optimizer, ParamSet, ParamSpec, Task};
use rigl::topology::{update_masks, Grow};
use rigl::util::{bench, Rng};

fn synth_def(n: usize) -> ModelDef {
    ModelDef {
        name: format!("synth{n}"),
        backend: "jnp".into(),
        optimizer: Optimizer::SgdMomentum,
        task: Task::Classify,
        input_ty: ElemType::F32,
        input_shape: vec![1, 1],
        target_shape: vec![1],
        hyper: vec![],
        artifacts: vec![],
        specs: vec![ParamSpec {
            name: "w".into(),
            kind: Kind::Fc,
            sparsifiable: true,
            first_layer: false,
            flops: 0.0,
            shape: vec![n, 1],
        }],
    }
}

fn main() {
    println!("== bench_topology: one Algorithm-1 mask update ==");
    for n in [10_000usize, 100_000, 1_000_000, 4_000_000] {
        let def = synth_def(n);
        let mut rng = Rng::new(0);
        let mut params = ParamSet::init(&def, &mut rng);
        let mut masks = ParamSet::zeros(&def);
        for i in 0..n / 10 {
            masks.tensors[0][i * 10] = 1.0; // 10% dense
        }
        let mut grads = ParamSet::init(&def, &mut rng);
        let mut mom = ParamSet::zeros(&def);
        bench(&format!("rigl_update/n={n}"), 10, || {
            let mut g2 = grads.clone();
            std::mem::swap(&mut g2, &mut grads);
            let mut bufs: Vec<&mut ParamSet> = vec![&mut mom];
            update_masks(&def, &mut params, &mut bufs, &mut masks, 0.3, Grow::Gradient(&grads));
        });
        let mut rng2 = Rng::new(7);
        bench(&format!("set_update/n={n}"), 10, || {
            let mut bufs: Vec<&mut ParamSet> = vec![&mut mom];
            update_masks(&def, &mut params, &mut bufs, &mut masks, 0.3, Grow::Random(&mut rng2));
        });
    }
}
