//! Coordinator-level before/after benchmark → `BENCH_coordinator.json`.
//!
//! Measures the two claims of the thread-parallel/allocation-free PR:
//!
//! 1. **Topology hot path** — one Algorithm-1 update through the
//!    allocating wrapper (`fresh_scratch`, the seed's allocation
//!    pattern) vs the reused-scratch hot path, on a 1M-element layer.
//! 2. **Cell fan-out** — wall-clock of a 4-seed `run_cell` at
//!    `--jobs 1` vs `--jobs 4` (requires AOT artifacts; skipped with a
//!    note otherwise). The ≥2× acceptance target lives here.
//!
//! Run with `cargo bench --bench bench_coordinator`; records append as
//! JSON lines, so history accumulates across commits.

use rigl::model::{ElemType, Kind, ModelDef, Optimizer, ParamSet, ParamSpec, Task};
use rigl::topology::{update_masks, update_masks_scratch, Grow, Method, TopoScratch, UpdateStats};
use rigl::util::{append_bench_record, bench_to, git_rev, smoke_mode, BenchRecord, Rng};

fn synth_def(n: usize) -> ModelDef {
    ModelDef {
        name: format!("synth{n}"),
        backend: "jnp".into(),
        optimizer: Optimizer::SgdMomentum,
        task: Task::Classify,
        input_ty: ElemType::F32,
        input_shape: vec![1, 1],
        target_shape: vec![1],
        hyper: vec![],
        artifacts: vec![],
        specs: vec![ParamSpec {
            name: "w".into(),
            kind: Kind::Fc,
            sparsifiable: true,
            first_layer: false,
            flops: 0.0,
            shape: vec![n, 1],
        }],
    }
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    println!(
        "== bench_coordinator: hot-path + fan-out wall-clock{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let reps = if smoke { 2 } else { 10 };

    // ---------------- topology before/after (always runs) ------------
    let n = if smoke { 10_000usize } else { 1_000_000 };
    let def = synth_def(n);
    let mut rng = Rng::new(0);
    let mut params = ParamSet::init(&def, &mut rng);
    let mut masks = ParamSet::zeros(&def);
    for i in 0..n / 10 {
        masks.tensors[0][i * 10] = 1.0;
    }
    let grads = ParamSet::init(&def, &mut rng);
    let mut mom = ParamSet::zeros(&def);
    bench_to("coordinator", &format!("update_masks/fresh_scratch/n={n}"), reps, || {
        update_masks(
            &def,
            &mut params,
            std::slice::from_mut(&mut mom),
            &mut masks,
            0.3,
            Grow::Gradient(&grads),
        );
    });
    let mut scratch = TopoScratch::default();
    let mut stats = UpdateStats::default();
    bench_to("coordinator", &format!("update_masks/reused_scratch/n={n}"), reps, || {
        update_masks_scratch(
            &def,
            &mut params,
            std::slice::from_mut(&mut mom),
            &mut masks,
            0.3,
            Grow::Gradient(&grads),
            &mut scratch,
            &mut stats,
        );
    });

    // ---------------- cell fan-out (needs AOT artifacts) --------------
    if !rigl::artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping run_cell fan-out bench: artifacts not built (`make artifacts`)");
        return Ok(());
    }
    use rigl::coordinator::ExpContext;
    let mut walls = Vec::new();
    for jobs in [1usize, 4] {
        let mut ctx = ExpContext::new(4, 1.0, jobs, std::env::temp_dir())?;
        ctx.verbose = false;
        let mut cfg = ctx.base("mlp", Method::Rigl);
        cfg.sparsity = 0.9;
        cfg.steps = if smoke { 20 } else { 100 };
        cfg.delta_t = 25;
        cfg.augment = false;
        cfg.data_train = 512;
        cfg.data_val = 256;
        // Warm the compile + trainer caches so wall-clock is training only.
        ctx.run_cell("warmup", &cfg)?;
        let t0 = std::time::Instant::now();
        let cell = ctx.run_cell("bench", &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        println!("run_cell/jobs={jobs}: {wall:.2}s over 4 seeds (metrics {:?})", cell.metrics);
        append_bench_record(
            "coordinator",
            &BenchRecord {
                name: format!("run_cell/4seeds/jobs={jobs}"),
                iters: 1,
                mean_s: wall,
                min_s: wall,
                gflops: None,
                git_rev: git_rev(),
                unix_ms: rigl::util::unix_ms(),
            },
        )?;
        walls.push(wall);
    }
    if walls.len() == 2 && walls[1] > 0.0 {
        println!("speedup jobs=4 vs jobs=1: {:.2}x", walls[0] / walls[1]);
    }
    Ok(())
}
