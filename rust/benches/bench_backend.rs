//! Native-backend step-time scaling → `BENCH_backend.json`.
//!
//! The point of the native CSR engine is that measured wall-clock — not
//! just the Appendix-H FLOPs accounting — scales with (1 − sparsity).
//! This bench times one masked train step (forward + backward + SGDM)
//! and one dense-gradient call on the LeNet-300-100-scale MLP at several
//! sparsity levels, plus a short end-to-end RigL run, and appends JSON
//! lines so the trajectory is tracked commit over commit.
//!
//! Runs hermetically: no artifacts, no PJRT, no feature flags needed
//! (`cargo bench --bench bench_backend`).

use rigl::backend::native::{mlp_def, NativeBackend};
use rigl::backend::{Backend, Session as _};
use rigl::model::ParamSet;
use rigl::sparsity::{layer_sparsities, random_masks, Distribution};
use rigl::train::{Batch, TrainState};
use rigl::util::{bench_to, Rng};

fn state_at_sparsity(def: &rigl::ModelDef, sparsity: f64, rng: &mut Rng) -> TrainState {
    let mut params = ParamSet::init(def, &mut rng.split(1));
    let masks = if sparsity > 0.0 {
        let s = layer_sparsities(def, sparsity, &Distribution::Uniform);
        random_masks(def, &s, &mut rng.split(2))
    } else {
        ParamSet::ones(def)
    };
    params.mul_assign(&masks);
    TrainState {
        params,
        opt: vec![ParamSet::zeros(def)],
        adam_t: 0.0,
        masks,
        step: 0,
    }
}

fn main() -> anyhow::Result<()> {
    println!("== bench_backend: native CSR engine step-time vs sparsity ==");
    let batch = 32;
    let def = mlp_def("bench_mlp", 784, &[512, 256], 10, batch);
    let be = NativeBackend::new(&def)?;
    let mut rng = Rng::new(0xBE);
    let x = Batch::F32((0..batch * 784).map(|_| rng.next_f32()).collect());
    let y: Vec<i32> = (0..batch).map(|_| rng.next_below(10) as i32).collect();

    // Per-step cost at increasing density: mean step time should grow
    // roughly linearly with nnz (the dense output layer is a constant
    // floor shared by all levels).
    let mut means = Vec::new();
    for &s in &[0.98f64, 0.9, 0.5, 0.0] {
        let mut state = state_at_sparsity(&def, s, &mut rng);
        let mut sess = be.session(&state)?;
        let mean = bench_to(
            "backend",
            &format!("native/train_step/b={batch}/S={s}"),
            50,
            || {
                sess.train_step(&mut state, &x, &y, 0.01).unwrap();
            },
        );
        means.push((s, mean));
    }
    if let (Some(sparse), Some(dense)) =
        (means.iter().find(|m| m.0 == 0.9), means.iter().find(|m| m.0 == 0.0))
    {
        println!(
            "step-time ratio dense/S=0.9: {:.2}x (ideal ≈ {:.1}x on the sparsifiable share)",
            dense.1 / sparse.1,
            1.0 / 0.1
        );
    }

    // The RigL grow signal stays an O(dense) outer product — measured
    // here so the ΔT amortization argument has both terms on record.
    {
        let mut state = state_at_sparsity(&def, 0.9, &mut rng);
        let mut sess = be.session(&state)?;
        bench_to("backend", &format!("native/dense_grads/b={batch}/S=0.9"), 20, || {
            sess.dense_grads(&state, &x, &y).unwrap();
        });
    }

    // End-to-end: a tiny RigL run through the Trainer (data pipeline,
    // topology updates, evals included).
    {
        use rigl::topology::Method;
        use rigl::train::{TrainConfig, Trainer};
        let def = mlp_def("bench_mlp_e2e", 784, &[128, 64], 10, 16);
        let mut cfg = TrainConfig::new("bench_mlp_e2e", Method::Rigl);
        cfg.sparsity = 0.9;
        cfg.steps = 100;
        cfg.delta_t = 25;
        cfg.augment = false;
        cfg.data_train = 512;
        cfg.data_val = 256;
        let backend = std::sync::Arc::new(NativeBackend::new(&def)?);
        let trainer = Trainer::from_parts(def, backend, &cfg)?;
        bench_to("backend", "native/rigl_run/100steps/S=0.9", 3, || {
            trainer.run(&cfg).unwrap();
        });
    }
    Ok(())
}
