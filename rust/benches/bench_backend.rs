//! Native-backend step-time scaling → `BENCH_backend.json`.
//!
//! The point of the native CSR engine is that measured wall-clock — not
//! just the Appendix-H FLOPs accounting — scales with (1 − sparsity),
//! with `--threads` (blocked kernels), and with SIMD lane width (the
//! batch-panel kernels). This bench times one masked train step
//! (forward + backward + SGDM) over the full sparsity × threads ×
//! lanes grid on the LeNet-300-100-scale MLP — `lanes=8` is the
//! batch-panel path, `lanes=1` forces the scalar loops via
//! `kernels::set_panel_kernels` — plus one dense-gradient grid and a
//! short end-to-end RigL run, appending JSON lines so the trajectory is
//! tracked commit over commit. Step cells carry an effective-GFLOP/s
//! field (useful sparse FLOPs retired per second: ~6·nnz·batch per
//! step, counting forward + both backward products, NOT the dense
//! equivalent).
//!
//! Every cell is also verified BIT-identical to `t=1, lanes=1` (the
//! kernels' determinism contract now includes the lane axis): a fixed
//! number of train steps from an identical init must leave identical
//! state, or the bench exits non-zero — making the contract a CI gate,
//! not just a test. The acceptance target from the panel rewrite —
//! `lanes=8` beating `lanes=1` by ≥2× on the S=0.9 step at batch ≥ 8 —
//! is printed (and loudly flagged when missed in full mode; smoke-mode
//! shapes are too small to judge).
//!
//! Runs hermetically: no artifacts, no PJRT, no feature flags needed
//! (`cargo bench --bench bench_backend`; `-- --smoke` for the tiny CI
//! variant).

use std::sync::Arc;

use rigl::backend::native::kernels::set_panel_kernels;
use rigl::backend::native::{mlp_def, NativeBackend};
use rigl::backend::{Backend, Session as _};
use rigl::model::ParamSet;
use rigl::pool::KernelPool;
use rigl::sparsity::{layer_sparsities, random_masks, Distribution};
use rigl::train::{Batch, TrainState};
use rigl::util::{bench_to, bench_to_flops, smoke_mode, Rng};

fn state_at_sparsity(def: &rigl::ModelDef, sparsity: f64, rng: &mut Rng) -> TrainState {
    let mut params = ParamSet::init(def, &mut rng.split(1));
    let masks = if sparsity > 0.0 {
        let s = layer_sparsities(def, sparsity, &Distribution::Uniform);
        random_masks(def, &s, &mut rng.split(2))
    } else {
        ParamSet::ones(def)
    };
    params.mul_assign(&masks);
    TrainState {
        params,
        opt: vec![ParamSet::zeros(def)],
        adam_t: 0.0,
        masks,
        step: 0,
    }
}

/// Useful FLOPs in one masked train step: forward + data-backward +
/// weight-backward are each one 2·nnz multiply-add stream per batch
/// row (the first layer has no data-backward).
fn step_flops(def: &rigl::ModelDef, state: &TrainState, batch: usize) -> f64 {
    let nnz: Vec<f64> = def
        .specs
        .iter()
        .zip(&state.masks.tensors)
        .filter(|(spec, _)| spec.shape.len() == 2)
        .map(|(_, m)| m.iter().filter(|&&v| v != 0.0).count() as f64)
        .collect();
    let total: f64 = nnz.iter().sum();
    let first = nnz.first().copied().unwrap_or(0.0);
    batch as f64 * (6.0 * total - 2.0 * first)
}

/// `check_steps` train steps from a fixed init at the given lane
/// setting: the resulting params as bit patterns (the cross-thread,
/// cross-lane identity probe).
fn probe_state(
    def: &rigl::ModelDef,
    threads: usize,
    lanes: usize,
    sparsity: f64,
    x: &Batch,
    y: &[i32],
    check_steps: usize,
) -> Vec<u32> {
    let was = set_panel_kernels(lanes > 1);
    // Pin the pool's autotune floor to 1: the probe exists to verify the
    // POOLED paths bit-identical, and a slow runner's measured floor
    // could otherwise silently keep every cell on the flat path.
    let pool = (threads > 1).then(|| Arc::new(KernelPool::with_par_min_ops(threads, 1)));
    let be = NativeBackend::with_pool(def, pool).unwrap();
    let mut rng = Rng::new(0xB17);
    let mut state = state_at_sparsity(def, sparsity, &mut rng);
    let mut sess = be.session(&state).unwrap();
    for _ in 0..check_steps {
        sess.train_step(&mut state, x, y, 0.01).unwrap();
    }
    drop(sess);
    set_panel_kernels(was);
    state
        .params
        .tensors
        .iter()
        .flat_map(|t| t.iter().map(|v| v.to_bits()))
        .collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    println!(
        "== bench_backend: native CSR engine step-time vs sparsity × threads × lanes{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let batch = 32;
    let def = mlp_def("bench_mlp", 784, &[512, 256], 10, batch);
    let mut rng = Rng::new(0xBE);
    let x = Batch::F32((0..batch * 784).map(|_| rng.next_f32()).collect());
    let y: Vec<i32> = (0..batch).map(|_| rng.next_below(10) as i32).collect();

    let sparsities: &[f64] = if smoke { &[0.9] } else { &[0.98, 0.9, 0.5, 0.0] };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let lane_widths: &[usize] = &[1, 8];
    let iters = if smoke { 3 } else { 50 };
    let check_steps = if smoke { 2 } else { 5 };

    // Per-step cost over the full grid. At fixed (t, lanes), mean step
    // time should grow roughly linearly with nnz; at fixed S it should
    // shrink with threads (until the measured autotune floor keeps tiny
    // layers serial) and with lanes (the panel rewrite's headline).
    let mut means = Vec::new();
    let mut identical = true;
    for &s in sparsities {
        let baseline = probe_state(&def, 1, 1, s, &x, &y, check_steps);
        let flops = {
            let st = state_at_sparsity(&def, s, &mut Rng::new(0xB17));
            step_flops(&def, &st, batch)
        };
        for &t in thread_counts {
            for &lanes in lane_widths {
                let was = set_panel_kernels(lanes > 1);
                let be = NativeBackend::with_threads(&def, t)?;
                let mut state = state_at_sparsity(&def, s, &mut rng);
                let mut sess = be.session(&state)?;
                let mean = bench_to_flops(
                    "backend",
                    &format!("native/train_step/b={batch}/S={s}/t={t}/lanes={lanes}"),
                    iters,
                    Some(flops),
                    || {
                        sess.train_step(&mut state, &x, &y, 0.01).unwrap();
                    },
                );
                means.push((s, t, lanes, mean));
                drop(sess);
                set_panel_kernels(was);

                // The determinism gate: every cell bit-identical to
                // t=1, lanes=1.
                if (t > 1 || lanes > 1)
                    && probe_state(&def, t, lanes, s, &x, &y, check_steps) != baseline
                {
                    identical = false;
                    eprintln!("REGRESSION: S={s} t={t} lanes={lanes} diverged from serial/scalar");
                }
            }
        }
    }
    let cell = |s: f64, t: usize, l: usize| {
        means.iter().find(|m| m.0 == s && m.1 == t && m.2 == l).map(|m| m.3)
    };
    if let (Some(sp), Some(dn)) = (cell(0.9, 1, 8), cell(0.0, 1, 8)) {
        println!(
            "step-time ratio dense/S=0.9 (serial, lanes=8): {:.2}x (ideal ≈ {:.1}x on the \
             sparsifiable share)",
            dn / sp,
            1.0 / 0.1
        );
    }
    if let (Some(t1), Some(t4)) = (cell(0.9, 1, 8), cell(0.9, 4, 8)) {
        println!("step-time speedup S=0.9 t=4 vs t=1 (lanes=8): {:.2}x", t1 / t4);
    }
    if let (Some(scalar), Some(panel)) = (cell(0.9, 1, 1), cell(0.9, 1, 8)) {
        let speedup = scalar / panel;
        println!("panel speedup S=0.9 t=1, lanes=8 vs lanes=1: {speedup:.2}x (target ≥ 2x)");
        if !smoke && speedup < 2.0 {
            // Not an exit-1 gate (machine dependent), but loud: the
            // acceptance criteria say a miss must be investigated.
            eprintln!(
                "PANEL SPEEDUP BELOW TARGET: {speedup:.2}x < 2x on the S=0.9 step — check \
                 autovectorization (RUSTFLAGS=-Ctarget-cpu=x86-64-v3, or enable simd-intrinsics)"
            );
        }
    }

    // The RigL grow signal stays an O(dense) outer product — measured
    // per thread count and lane width so the ΔT amortization argument
    // has all terms on record (dense grads parallelize best: uniform
    // chunks and contiguous panel FMAs).
    for &t in thread_counts {
        for &lanes in lane_widths {
            let was = set_panel_kernels(lanes > 1);
            let be = NativeBackend::with_threads(&def, t)?;
            let mut state = state_at_sparsity(&def, 0.9, &mut rng);
            let mut sess = be.session(&state)?;
            bench_to(
                "backend",
                &format!("native/dense_grads/b={batch}/S=0.9/t={t}/lanes={lanes}"),
                if smoke { 2 } else { 20 },
                || {
                    sess.dense_grads(&state, &x, &y).unwrap();
                },
            );
            drop(sess);
            set_panel_kernels(was);
        }
    }

    // End-to-end: a tiny RigL run through the Trainer (data pipeline,
    // topology updates, evals included) with panels at the default (on).
    {
        use rigl::topology::Method;
        use rigl::train::{TrainConfig, Trainer};
        let def = mlp_def("bench_mlp_e2e", 784, &[128, 64], 10, 16);
        let mut cfg = TrainConfig::new("bench_mlp_e2e", Method::Rigl);
        cfg.sparsity = 0.9;
        cfg.steps = if smoke { 20 } else { 100 };
        cfg.delta_t = if smoke { 5 } else { 25 };
        cfg.augment = false;
        cfg.data_train = 512;
        cfg.data_val = 256;
        let backend = std::sync::Arc::new(NativeBackend::new(&def)?);
        let trainer = Trainer::from_parts(def, backend, &cfg)?;
        bench_to(
            "backend",
            &format!("native/rigl_run/{}steps/S=0.9", cfg.steps),
            if smoke { 1 } else { 3 },
            || {
                trainer.run(&cfg).unwrap();
            },
        );
    }

    if !identical {
        std::process::exit(1);
    }
    Ok(())
}
