//! Native-backend step-time scaling → `BENCH_backend.json`.
//!
//! The point of the native CSR engine is that measured wall-clock — not
//! just the Appendix-H FLOPs accounting — scales with (1 − sparsity),
//! and (since the blocked-kernel engine) with `--threads`. This bench
//! times one masked train step (forward + backward + SGDM) over the
//! full threads × sparsity grid on the LeNet-300-100-scale MLP, one
//! dense-gradient call per thread count, and a short end-to-end RigL
//! run, appending JSON lines so the trajectory is tracked commit over
//! commit.
//!
//! Every threaded cell is also verified BIT-identical to `threads=1`
//! (the kernels' determinism contract): a fixed number of train steps
//! from an identical init must leave identical state, or the bench
//! exits non-zero — making the contract a CI gate, not just a test.
//!
//! Runs hermetically: no artifacts, no PJRT, no feature flags needed
//! (`cargo bench --bench bench_backend`; `-- --smoke` for the tiny CI
//! variant).

use rigl::backend::native::{mlp_def, NativeBackend};
use rigl::backend::{Backend, Session as _};
use rigl::model::ParamSet;
use rigl::sparsity::{layer_sparsities, random_masks, Distribution};
use rigl::train::{Batch, TrainState};
use rigl::util::{bench_to, smoke_mode, Rng};

fn state_at_sparsity(def: &rigl::ModelDef, sparsity: f64, rng: &mut Rng) -> TrainState {
    let mut params = ParamSet::init(def, &mut rng.split(1));
    let masks = if sparsity > 0.0 {
        let s = layer_sparsities(def, sparsity, &Distribution::Uniform);
        random_masks(def, &s, &mut rng.split(2))
    } else {
        ParamSet::ones(def)
    };
    params.mul_assign(&masks);
    TrainState {
        params,
        opt: vec![ParamSet::zeros(def)],
        adam_t: 0.0,
        masks,
        step: 0,
    }
}

/// `check_steps` train steps from a fixed init: the resulting params as
/// bit patterns (the cross-thread identity probe).
fn probe_state(
    def: &rigl::ModelDef,
    threads: usize,
    sparsity: f64,
    x: &Batch,
    y: &[i32],
    check_steps: usize,
) -> Vec<u32> {
    let be = NativeBackend::with_threads(def, threads).unwrap();
    let mut rng = Rng::new(0xB17);
    let mut state = state_at_sparsity(def, sparsity, &mut rng);
    let mut sess = be.session(&state).unwrap();
    for _ in 0..check_steps {
        sess.train_step(&mut state, x, y, 0.01).unwrap();
    }
    drop(sess);
    state
        .params
        .tensors
        .iter()
        .flat_map(|t| t.iter().map(|v| v.to_bits()))
        .collect()
}

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    println!(
        "== bench_backend: native CSR engine step-time vs sparsity × threads{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    let batch = 32;
    let def = mlp_def("bench_mlp", 784, &[512, 256], 10, batch);
    let mut rng = Rng::new(0xBE);
    let x = Batch::F32((0..batch * 784).map(|_| rng.next_f32()).collect());
    let y: Vec<i32> = (0..batch).map(|_| rng.next_below(10) as i32).collect();

    let sparsities: &[f64] = if smoke { &[0.9] } else { &[0.98, 0.9, 0.5, 0.0] };
    let thread_counts: &[usize] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };
    let iters = if smoke { 3 } else { 50 };
    let check_steps = if smoke { 2 } else { 5 };

    // Per-step cost over the full grid. At fixed threads, mean step time
    // should grow roughly linearly with nnz; at fixed sparsity it should
    // shrink with threads (until the autotune floor keeps tiny layers
    // serial).
    let mut means = Vec::new();
    let mut identical = true;
    for &s in sparsities {
        let baseline = probe_state(&def, 1, s, &x, &y, check_steps);
        for &t in thread_counts {
            let be = NativeBackend::with_threads(&def, t)?;
            let mut state = state_at_sparsity(&def, s, &mut rng);
            let mut sess = be.session(&state)?;
            let mean = bench_to(
                "backend",
                &format!("native/train_step/b={batch}/S={s}/t={t}"),
                iters,
                || {
                    sess.train_step(&mut state, &x, &y, 0.01).unwrap();
                },
            );
            means.push((s, t, mean));
            drop(sess);

            // The determinism gate: every cell bit-identical to t=1.
            if t > 1 && probe_state(&def, t, s, &x, &y, check_steps) != baseline {
                identical = false;
                eprintln!("REGRESSION: S={s} t={t} diverged from the serial path");
            }
        }
    }
    if let (Some(sp), Some(dn)) = (
        means.iter().find(|m| m.0 == 0.9 && m.1 == 1),
        means.iter().find(|m| m.0 == 0.0 && m.1 == 1),
    ) {
        println!(
            "step-time ratio dense/S=0.9 (serial): {:.2}x (ideal ≈ {:.1}x on the sparsifiable share)",
            dn.2 / sp.2,
            1.0 / 0.1
        );
    }
    if let (Some(t1), Some(t4)) = (
        means.iter().find(|m| m.0 == 0.9 && m.1 == 1),
        means.iter().find(|m| m.0 == 0.9 && m.1 == 4),
    ) {
        println!("step-time speedup S=0.9 t=4 vs t=1: {:.2}x", t1.2 / t4.2);
    }

    // The RigL grow signal stays an O(dense) outer product — measured
    // per thread count so the ΔT amortization argument has both terms
    // on record (dense grads parallelize best: uniform chunks).
    for &t in thread_counts {
        let be = NativeBackend::with_threads(&def, t)?;
        let mut state = state_at_sparsity(&def, 0.9, &mut rng);
        let mut sess = be.session(&state)?;
        bench_to(
            "backend",
            &format!("native/dense_grads/b={batch}/S=0.9/t={t}"),
            if smoke { 2 } else { 20 },
            || {
                sess.dense_grads(&state, &x, &y).unwrap();
            },
        );
        drop(sess);
    }

    // End-to-end: a tiny RigL run through the Trainer (data pipeline,
    // topology updates, evals included).
    {
        use rigl::topology::Method;
        use rigl::train::{TrainConfig, Trainer};
        let def = mlp_def("bench_mlp_e2e", 784, &[128, 64], 10, 16);
        let mut cfg = TrainConfig::new("bench_mlp_e2e", Method::Rigl);
        cfg.sparsity = 0.9;
        cfg.steps = if smoke { 20 } else { 100 };
        cfg.delta_t = if smoke { 5 } else { 25 };
        cfg.augment = false;
        cfg.data_train = 512;
        cfg.data_val = 256;
        let backend = std::sync::Arc::new(NativeBackend::new(&def)?);
        let trainer = Trainer::from_parts(def, backend, &cfg)?;
        bench_to(
            "backend",
            &format!("native/rigl_run/{}steps/S=0.9", cfg.steps),
            if smoke { 1 } else { 3 },
            || {
                trainer.run(&cfg).unwrap();
            },
        );
    }

    if !identical {
        std::process::exit(1);
    }
    Ok(())
}
