//! Synthetic data pipeline throughput: generation, batching, augmentation.

use rigl::data::{augment_batch, BatchIter, CharDataset, DigitDataset, ImageDataset};
use rigl::util::{bench, smoke_mode, Rng};

fn main() {
    let smoke = smoke_mode();
    println!(
        "== bench_data: generation + batch + augment{} ==",
        if smoke { " [SMOKE]" } else { "" }
    );
    // Smoke mode (CI): tiny datasets, 1 rep — exercises every code path
    // without measurement-grade run time.
    let (n_img, n_dig, n_chr) = if smoke { (64, 128, 5_000) } else { (1024, 2048, 100_000) };
    let gen_reps = if smoke { 1 } else { 3 };
    let loop_reps = if smoke { 5 } else { 200 };
    bench(&format!("gen/images {n_img}x32x32x3"), gen_reps, || {
        let _ = ImageDataset::synth(n_img, 32, 10, 0.35, 7);
    });
    bench(&format!("gen/digits {n_dig}x784"), gen_reps, || {
        let _ = DigitDataset::synth(n_dig, 10, 0.6, 7);
    });
    bench(&format!("gen/chars {n_chr}"), gen_reps, || {
        let _ = CharDataset::synth(n_chr, 64, 2.0, 7);
    });

    let img = ImageDataset::synth(n_img, 32, 10, 0.35, 7);
    let mut it = BatchIter::new(n_img, 32, 0);
    bench("gather/images b32", loop_reps, || {
        let idx = it.next_indices().to_vec();
        let _ = img.gather(&idx);
    });
    let (mut x, _) = img.gather(&(0..32).collect::<Vec<_>>());
    let mut rng = Rng::new(1);
    bench("augment/images b32", loop_reps, || {
        augment_batch(&mut x, 32, 32, 32, 3, &mut rng);
    });
    let chars = CharDataset::synth(n_chr, 64, 2.0, 7);
    let mut rng2 = Rng::new(2);
    bench("batch/chars b16xT48", if smoke { 10 } else { 500 }, || {
        let _ = chars.batch(16, 48, &mut rng2);
    });
}
