//! Synthetic data pipeline throughput: generation, batching, augmentation.

use rigl::data::{augment_batch, BatchIter, CharDataset, DigitDataset, ImageDataset};
use rigl::util::{bench, Rng};

fn main() {
    println!("== bench_data: generation + batch + augment ==");
    bench("gen/images 1024x32x32x3", 3, || {
        let _ = ImageDataset::synth(1024, 32, 10, 0.35, 7);
    });
    bench("gen/digits 2048x784", 3, || {
        let _ = DigitDataset::synth(2048, 10, 0.6, 7);
    });
    bench("gen/chars 100k", 3, || {
        let _ = CharDataset::synth(100_000, 64, 2.0, 7);
    });

    let img = ImageDataset::synth(1024, 32, 10, 0.35, 7);
    let mut it = BatchIter::new(1024, 32, 0);
    bench("gather/images b32", 200, || {
        let idx = it.next_indices().to_vec();
        let _ = img.gather(&idx);
    });
    let (mut x, _) = img.gather(&(0..32).collect::<Vec<_>>());
    let mut rng = Rng::new(1);
    bench("augment/images b32", 200, || {
        augment_batch(&mut x, 32, 32, 32, 3, &mut rng);
    });
    let chars = CharDataset::synth(100_000, 64, 2.0, 7);
    let mut rng2 = Rng::new(2);
    bench("batch/chars b16xT48", 500, || {
        let _ = chars.batch(16, 48, &mut rng2);
    });
}
