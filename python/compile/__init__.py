"""Build-time compile path: L2 JAX models + L1 Pallas kernels → HLO text.

Nothing in this package is imported at runtime; ``aot.py`` lowers every
(model, step) pair once and the rust coordinator consumes the HLO-text
artifacts through PJRT. See DESIGN.md for the three-layer architecture.
"""
