"""AOT lowering: every (model, step) pair → HLO **text** + manifest.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction ids which
the rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Python runs ONCE (``make artifacts``); the rust binary is self-contained
afterwards. The manifest is a simple line-oriented format (the rust side
has no JSON dependency available offline):

    # rigl artifact manifest v1
    backend jnp
    model <name>
    opt sgdm|adam
    task classify|lm
    batch <B>
    input f32|i32 <dims...>
    target i32 <dims...>
    hyper <key> <value>
    artifact train|densegrad|eval <file>
    param <name> <kind> <sparsifiable:0|1> <first_layer:0|1> <dims...>
    end

Usage: ``python -m compile.aot --out-dir ../artifacts [--models a,b,...]
[--backend jnp|pallas]``.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax

from . import kernels, steps
from .models import cnn, gru, mlp, mobilenet
from .models.common import Model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Registry: manifest name → (builder, backend override)
# Small-dense widths are chosen so parameter counts match the sparse
# networks they baseline (paper Fig. 2 "Small-Dense"); the flops engine on
# the rust side reports the exact counts.
# ---------------------------------------------------------------------------

REGISTRY = {
    # Appendix B / Table 2 track + rust kernel-path integration tests.
    "mlp": lambda: mlp.build("mlp"),
    "mlp_pallas": lambda: mlp.build("mlp_pallas"),  # built with --backend pallas
    "mlp_sd80": lambda: mlp.build("mlp_sd80", hidden=(64, 22)),
    "mlp_sd90": lambda: mlp.build("mlp_sd90", hidden=(31, 11)),
    "mlp_riglplus": lambda: mlp.build("mlp_riglplus", input_dim=784, hidden=(100, 69)),
    # ResNet-50 stand-in for the Fig. 2 sweeps (WRN-10-1, fast on CPU).
    "cnn": lambda: cnn.build("cnn", depth=10, width=1.0, batch_size=16),
    "cnn_sd80": lambda: cnn.build("cnn_sd80", depth=10, width=0.45, batch_size=16),
    "cnn_sd90": lambda: cnn.build("cnn_sd90", depth=10, width=0.32, batch_size=16),
    # WRN-16-2: the CIFAR-10 WRN-22-2 track + the e2e example model.
    "wrn": lambda: cnn.build("wrn", depth=16, width=2.0, batch_size=16),
    # MobileNet track (Fig. 3) incl. the Big-Sparse width experiment.
    "mobilenet": lambda: mobilenet.build("mobilenet", width=1.0),
    "mobilenet_big": lambda: mobilenet.build("mobilenet_big", width=2.0),
    "mobilenet_sd75": lambda: mobilenet.build("mobilenet_sd75", width=0.5),
    # Char-LM track (Fig. 4-left).
    "gru": lambda: gru.build("gru"),
}

PALLAS_MODELS = {"mlp_pallas"}

DEFAULT_MODELS = list(REGISTRY.keys())


def _sds_line(tag: str, sds) -> str:
    ty = {"float32": "f32", "int32": "i32"}[str(sds.dtype)]
    dims = " ".join(str(d) for d in sds.shape)
    return f"{tag} {ty} {dims}".rstrip()


def lower_model(model: Model, out_dir: str, backend: str) -> list[str]:
    """Lower train/densegrad/eval for one model; return manifest lines."""
    kernels.set_backend(backend)
    lines = [
        f"model {model.name}",
        f"backend {backend}",
        f"opt {model.optimizer}",
        f"task {model.task}",
        _sds_line("input", model.input_sds),
        _sds_line("target", model.target_sds),
    ]
    for k, v in sorted(model.hyper.items()):
        lines.append(f"hyper {k} {v}")
    jobs = [
        ("train", steps.make_train_step(model), steps.train_input_sds(model)),
        ("densegrad", steps.make_dense_grad(model), steps.densegrad_input_sds(model)),
        ("eval", steps.make_eval_step(model), steps.eval_input_sds(model)),
    ]
    for tag, fn, sds in jobs:
        t0 = time.time()
        lowered = jax.jit(fn).lower(*sds)
        text = to_hlo_text(lowered)
        fname = f"{model.name}_{tag}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(
            f"  {model.name}/{tag}: {len(sds)} inputs, "
            f"{len(text) / 1e6:.2f} MB HLO, {time.time() - t0:.1f}s",
            flush=True,
        )
        lines.append(f"artifact {tag} {fname}")
    for s, fl in zip(model.specs, model.layer_flops):
        dims = " ".join(str(d) for d in s.shape)
        lines.append(
            f"param {s.name} {s.kind} {int(s.sparsifiable)} "
            f"{int(s.first_layer)} {fl:.1f} {dims}"
        )
    lines.append("end")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument(
        "--backend",
        default="",
        help="force one backend for ALL models (default: jnp, pallas for *_pallas)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = [n for n in args.models.split(",") if n]
    manifest = ["# rigl artifact manifest v1"]
    for name in names:
        if name not in REGISTRY:
            print(f"unknown model {name!r}; known: {sorted(REGISTRY)}", file=sys.stderr)
            sys.exit(2)
        backend = args.backend or ("pallas" if name in PALLAS_MODELS else "jnp")
        model = REGISTRY[name]()
        print(f"lowering {name} ({model.num_params} params, backend={backend})")
        manifest.extend(lower_model(model, args.out_dir, backend))
    path = os.path.join(args.out_dir, "manifest.txt")
    with open(path, "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
