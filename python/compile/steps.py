"""Step factories: the three AOT artifacts lowered per model.

Flat positional I/O (the rust coordinator indexes by position; order is
recorded in the manifest):

* ``train``      — one optimizer step on the masked network.
    sgdm: inputs  [P params][P momentum][P masks] x y lr
          outputs (P params', P momentum', loss)
    adam: inputs  [P params][P m][P v] t [P masks] x y lr
          outputs (P params', P m', P v', t', loss)
* ``densegrad``  — RigL's grow signal: gradients w.r.t. the FULL dense
    parameter tensors (∇_Θ L, nonzero on inactive connections), evaluated
    only every ΔT steps so the amortized cost stays ∝ (1−S) (paper §3(4)).
    inputs  [P params][P masks] x y
    outputs (S dense-grads..., S grow-scores..., loss)   [S = sparsifiable]
* ``eval``       — inputs [P params][P masks] x y → (metric_sum, count).
    classify: (Σ cross-entropy, Σ correct); lm: (Σ nats, token count).

Within a training step gradients are mask-chained (pruned weights stay
frozen); only ``densegrad`` sees the dense space. The optimizer step
re-masks its outputs so the ``params == params·mask`` invariant survives
float noise.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from . import kernels
from .models.common import (
    Model,
    classify_metrics,
    lm_metrics,
    smoothed_xent,
    token_xent,
)


def _loss(model: Model, logits, y):
    if model.task == "lm":
        return token_xent(logits, y)
    return smoothed_xent(logits, y, model.hyper.get("label_smoothing", 0.0))


def _clip_by_global_norm(grads: List[jax.Array], max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
    return [g * scale for g in grads]


def make_train_step(model: Model):
    p = len(model.specs)
    wd = model.hyper.get("weight_decay", 0.0)

    if model.optimizer == "sgdm":
        mu = model.hyper["momentum"]

        def train(*flat):
            params = list(flat[0:p])
            mom = list(flat[p : 2 * p])
            masks = list(flat[2 * p : 3 * p])
            x, y, lr = flat[3 * p], flat[3 * p + 1], flat[3 * p + 2]

            def loss_fn(ps):
                eff = [q * m for q, m in zip(ps, masks)]
                return _loss(model, model.apply(eff, x), y)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            new_p, new_m = [], []
            for q, g, v, m in zip(params, grads, mom, masks):
                g = g + wd * q  # q is already masked ⇒ decay stays masked
                v2 = mu * v + g
                new_m.append(v2 * m)
                new_p.append((q - lr * v2) * m)
            return (*new_p, *new_m, loss)

        return train

    assert model.optimizer == "adam"
    b1, b2, eps = model.hyper["b1"], model.hyper["b2"], model.hyper["eps"]
    clip = model.hyper.get("grad_clip", 0.0)

    def train(*flat):
        params = list(flat[0:p])
        m1 = list(flat[p : 2 * p])
        m2 = list(flat[2 * p : 3 * p])
        t = flat[3 * p]
        masks = list(flat[3 * p + 1 : 4 * p + 1])
        x, y, lr = flat[4 * p + 1], flat[4 * p + 2], flat[4 * p + 3]

        def loss_fn(ps):
            eff = [q * m for q, m in zip(ps, masks)]
            return _loss(model, model.apply(eff, x), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if clip > 0.0:
            grads = _clip_by_global_norm(grads, clip)
        t2 = t + 1.0
        c1 = 1.0 - jnp.power(b1, t2)
        c2 = 1.0 - jnp.power(b2, t2)
        new_p, new_m1, new_m2 = [], [], []
        for q, g, a, v, m in zip(params, grads, m1, m2, masks):
            g = g + wd * q
            a2 = b1 * a + (1.0 - b1) * g
            v2 = b2 * v + (1.0 - b2) * g * g
            step = (a2 / c1) / (jnp.sqrt(v2 / c2) + eps)
            new_m1.append(a2 * m)
            new_m2.append(v2 * m)
            new_p.append((q - lr * step) * m)
        return (*new_p, *new_m1, *new_m2, t2, loss)

    return train


def make_dense_grad(model: Model):
    p = len(model.specs)
    sparse_idx = [i for i, s in enumerate(model.specs) if s.sparsifiable]

    def densegrad(*flat):
        params = list(flat[0:p])
        masks = list(flat[p : 2 * p])
        x, y = flat[2 * p], flat[2 * p + 1]
        eff = [q * m for q, m in zip(params, masks)]

        def loss_fn(e):
            return _loss(model, model.apply(e, x), y)

        loss, grads = jax.value_and_grad(loss_fn)(eff)
        dense = [grads[i] for i in sparse_idx]
        scores = [
            kernels.rigl_scores(params[i], grads[i], masks[i])[1]
            for i in sparse_idx
        ]
        return (*dense, *scores, loss)

    return densegrad


def make_eval_step(model: Model):
    p = len(model.specs)
    metrics = lm_metrics if model.task == "lm" else classify_metrics

    def evaluate(*flat):
        params = list(flat[0:p])
        masks = list(flat[p : 2 * p])
        x, y = flat[2 * p], flat[2 * p + 1]
        eff = [q * m for q, m in zip(params, masks)]
        logits = model.apply(eff, x)
        s, c = metrics(logits, y)
        return (s, c)

    return evaluate


def train_input_sds(model: Model):
    """ShapeDtypeStructs for the train artifact, in manifest order."""
    ps = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.specs]
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    if model.optimizer == "sgdm":
        return [*ps, *ps, *ps, model.input_sds, model.target_sds, scalar]
    return [*ps, *ps, *ps, scalar, *ps, model.input_sds, model.target_sds, scalar]


def densegrad_input_sds(model: Model):
    ps = [jax.ShapeDtypeStruct(s.shape, jnp.float32) for s in model.specs]
    return [*ps, *ps, model.input_sds, model.target_sds]


def eval_input_sds(model: Model):
    return densegrad_input_sds(model)
