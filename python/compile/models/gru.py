"""GRU character-level language model — the paper's WikiText-103 track
(§4.2), scaled to the synthetic Markov corpus (DESIGN.md §2).

Architecture mirrors the paper's: shared embedding → GRU → two linear
readouts → tied-width softmax head. Trained with Adam (paper Appendix I).
All recurrent and readout matmuls route through the L1 masked-matmul
kernel inside a ``lax.scan`` over time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import Model, ParamSpec


def build(
    name: str = "gru",
    vocab: int = 64,
    emb: int = 64,
    state: int = 256,
    readouts=(128, 64),
    seq_len: int = 48,
    batch_size: int = 16,
) -> Model:
    r1, r2 = readouts
    specs = [
        # Embedding is the "first layer" (dense under Uniform).
        ParamSpec("emb/w", (vocab, emb), "emb", True, first_layer=True),
        ParamSpec("gru/wx", (emb, 3 * state), "fc", True),
        ParamSpec("gru/wh", (state, 3 * state), "fc", True),
        ParamSpec("gru/bx", (3 * state,), "bias"),
        ParamSpec("gru/bh", (3 * state,), "bias"),
        ParamSpec("ro1/w", (state, r1), "fc", True),
        ParamSpec("ro1/b", (r1,), "bias"),
        ParamSpec("ro2/w", (r1, r2), "fc", True),
        ParamSpec("ro2/b", (r2,), "bias"),
        ParamSpec("head/w", (r2, vocab), "fc", True),
        ParamSpec("head/b", (vocab,), "bias"),
    ]
    # Per-token forward FLOPs (embedding lookup ~0, matching the paper's
    # convention of omitting negligible ops).
    flops = [
        0.0,
        2.0 * emb * 3 * state,
        2.0 * state * 3 * state,
        0.0,
        0.0,
        2.0 * state * r1,
        0.0,
        2.0 * r1 * r2,
        0.0,
        2.0 * r2 * vocab,
        0.0,
    ]

    def apply(p, x):
        (w_emb, wx, wh, bx, bh, w1, b1, w2, b2, wo, bo) = p
        b, t = x.shape
        e = jnp.take(w_emb, x, axis=0)  # (B, T, E)
        # Hoist the input projection out of the scan: one big matmul on the
        # L1 kernel instead of T small ones.
        gx = common.dense(e.reshape(b * t, -1), wx).reshape(b, t, -1) + bx

        def cell(h, gx_t):
            gh = common.dense(h, wh) + bh
            xz, xr, xn = jnp.split(gx_t, 3, axis=-1)
            hz, hr, hn = jnp.split(gh, 3, axis=-1)
            z = jax.nn.sigmoid(xz + hz)
            r = jax.nn.sigmoid(xr + hr)
            n = jnp.tanh(xn + r * hn)
            h = (1.0 - z) * h + z * n
            return h, h

        h0 = jnp.zeros((b, state), jnp.float32)
        _, hs = jax.lax.scan(cell, h0, jnp.swapaxes(gx, 0, 1))
        hs = jnp.swapaxes(hs, 0, 1).reshape(b * t, state)  # (B*T, H)
        y = jax.nn.relu(common.dense(hs, w1) + b1)
        y = jax.nn.relu(common.dense(y, w2) + b2)
        logits = common.dense(y, wo) + bo
        return logits.reshape(b, t, vocab)

    return Model(
        name=name,
        specs=specs,
        apply=apply,
        layer_flops=flops,
        input_sds=jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
        target_sds=jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
        task="lm",
        optimizer="adam",
        hyper={"weight_decay": 5e-4, "b1": 0.9, "b2": 0.999, "eps": 1e-8,
               "grad_clip": 10.0},
    )
