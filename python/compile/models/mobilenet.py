"""MicroMobileNet — depthwise-separable stand-in for MobileNet-v1 (Fig. 3).

Follows the paper's MobileNet sparsification protocol exactly: the first
(stem) convolution and every depthwise convolution are KEPT DENSE (§4.1.2
"Due to its low parameter count we keep the first layer and depth-wise
convolutions dense"); only the pointwise 1×1 convolutions and the
classifier head are sparsifiable. Pointwise convs are pure matmuls and run
on the L1 kernel. ``width`` reproduces the Big-Sparse experiment (width
multiplier 1.98 at 75% sparsity ≈ dense FLOPs/params).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import Model, ParamSpec

# (channels_out, stride) per separable block, MobileNet-v1-shaped but
# shallow enough for the CPU testbed.
_BLOCKS = [(32, 1), (64, 2), (64, 1), (128, 2), (128, 1)]


def build(
    name: str = "mobilenet",
    width: float = 1.0,
    image_size: int = 32,
    channels: int = 3,
    num_classes: int = 10,
    batch_size: int = 32,
) -> Model:
    specs: list[ParamSpec] = []
    flops: list[float] = []
    plan: list[tuple] = []

    def add(spec, fl: float = 0.0):
        specs.append(spec)
        flops.append(fl)
        return len(specs) - 1

    hw = image_size
    c0 = max(8, int(16 * width))
    i_stem = add(
        ParamSpec("stem/w", (3, 3, channels, c0), "conv", False, first_layer=True),
        2.0 * 9 * channels * c0 * hw * hw,
    )
    i_sns = add(ParamSpec("stem/n/scale", (c0,), "norm"))
    i_snb = add(ParamSpec("stem/n/bias", (c0,), "bias"))
    plan.append(("stem", i_stem, i_sns, i_snb))

    cin = c0
    for bi, (craw, stride) in enumerate(_BLOCKS):
        cout = max(8, int(craw * width))
        hw = hw // stride
        pre = f"b{bi}"
        i_dw = add(
            ParamSpec(f"{pre}/dw/w", (3, 3, cin, 1), "conv", False),
            2.0 * 9 * cin * hw * hw,
        )
        i_dns = add(ParamSpec(f"{pre}/dwn/scale", (cin,), "norm"))
        i_dnb = add(ParamSpec(f"{pre}/dwn/bias", (cin,), "bias"))
        i_pw = add(
            ParamSpec(f"{pre}/pw/w", (1, 1, cin, cout), "conv", True),
            2.0 * cin * cout * hw * hw,
        )
        i_pns = add(ParamSpec(f"{pre}/pwn/scale", (cout,), "norm"))
        i_pnb = add(ParamSpec(f"{pre}/pwn/bias", (cout,), "bias"))
        plan.append(("sep", i_dw, i_dns, i_dnb, i_pw, i_pns, i_pnb, stride))
        cin = cout

    i_fc = add(ParamSpec("head/w", (cin, num_classes), "fc", True), 2.0 * cin * num_classes)
    i_fb = add(ParamSpec("head/b", (num_classes,), "bias"))
    plan.append(("head", i_fc, i_fb))

    def apply(p, x):
        h = x
        for op in plan:
            if op[0] == "stem":
                _, iw, ins, inb = op
                h = common.conv2d(h, p[iw], stride=1)
                h = jax.nn.relu(common.group_norm(h, p[ins], p[inb]))
            elif op[0] == "sep":
                _, i_dw, i_dns, i_dnb, i_pw, i_pns, i_pnb, stride = op
                h = common.depthwise_conv2d(h, p[i_dw], stride=stride)
                h = jax.nn.relu(common.group_norm(h, p[i_dns], p[i_dnb]))
                h = common.conv2d(h, p[i_pw], stride=1)
                h = jax.nn.relu(common.group_norm(h, p[i_pns], p[i_pnb]))
            else:
                _, iw, ib = op
                h = h.mean(axis=(1, 2))
                h = common.dense(h, p[iw]) + p[ib]
        return h

    return Model(
        name=name,
        specs=specs,
        apply=apply,
        layer_flops=flops,
        input_sds=jax.ShapeDtypeStruct(
            (batch_size, image_size, image_size, channels), jnp.float32
        ),
        target_sds=jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        task="classify",
        optimizer="sgdm",
        hyper={"weight_decay": 1e-4, "momentum": 0.9, "label_smoothing": 0.1},
    )
