"""Wide-ResNet-style CNN — the paper's CIFAR-10 (WRN-22-2) and the
scaled-down stand-in for ResNet-50 in the ImageNet-shaped experiments.

Pre-activation residual blocks, GroupNorm in place of BatchNorm (DESIGN.md
§2 substitution; norm affines stay dense exactly as the paper keeps BN
dense), every convolution lowered through im2col onto the L1 masked-matmul
kernel. ``depth`` follows the WRN convention: depth = 6n + 4 with n blocks
per group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common
from .common import Model, ParamSpec


def build(
    name: str = "cnn",
    depth: int = 10,
    width: float = 1.0,
    image_size: int = 32,
    channels: int = 3,
    num_classes: int = 10,
    batch_size: int = 32,
) -> Model:
    assert (depth - 4) % 6 == 0, "WRN depth must be 6n+4"
    n_blocks = (depth - 4) // 6
    widths = [16, int(16 * width), int(32 * width), int(64 * width)]
    specs: list[ParamSpec] = []
    flops: list[float] = []
    plan: list[tuple] = []  # layer program interpreted by apply()

    def add(spec: ParamSpec, fl: float = 0.0):
        specs.append(spec)
        flops.append(fl)
        return len(specs) - 1

    def conv_fl(kh, kw, ci, co, oh, ow):
        return 2.0 * kh * kw * ci * co * oh * ow

    # Stem (the "first layer": dense under Uniform, per paper §3(1)).
    hw = image_size
    i_stem = add(
        ParamSpec("stem/w", (3, 3, channels, widths[0]), "conv", True, first_layer=True),
        conv_fl(3, 3, channels, widths[0], hw, hw),
    )
    plan.append(("conv", i_stem, 1))

    cin = widths[0]
    for g, cout in enumerate(widths[1:], start=1):
        for b in range(n_blocks):
            stride = 2 if (g > 1 and b == 0) else 1
            ohw = hw // stride
            pre = f"g{g}b{b}"
            i_n1s = add(ParamSpec(f"{pre}/n1/scale", (cin,), "norm"))
            i_n1b = add(ParamSpec(f"{pre}/n1/bias", (cin,), "bias"))
            i_c1 = add(
                ParamSpec(f"{pre}/conv1/w", (3, 3, cin, cout), "conv", True),
                conv_fl(3, 3, cin, cout, ohw, ohw),
            )
            i_n2s = add(ParamSpec(f"{pre}/n2/scale", (cout,), "norm"))
            i_n2b = add(ParamSpec(f"{pre}/n2/bias", (cout,), "bias"))
            i_c2 = add(
                ParamSpec(f"{pre}/conv2/w", (3, 3, cout, cout), "conv", True),
                conv_fl(3, 3, cout, cout, ohw, ohw),
            )
            i_sc = None
            if stride != 1 or cin != cout:
                i_sc = add(
                    ParamSpec(f"{pre}/short/w", (1, 1, cin, cout), "conv", True),
                    conv_fl(1, 1, cin, cout, ohw, ohw),
                )
            plan.append(("block", i_n1s, i_n1b, i_c1, i_n2s, i_n2b, i_c2, i_sc, stride))
            cin = cout
            hw = ohw

    i_fns = add(ParamSpec("final/scale", (cin,), "norm"))
    i_fnb = add(ParamSpec("final/bias", (cin,), "bias"))
    i_fc = add(ParamSpec("head/w", (cin, num_classes), "fc", True), 2.0 * cin * num_classes)
    i_fb = add(ParamSpec("head/b", (num_classes,), "bias"))
    plan.append(("head", i_fns, i_fnb, i_fc, i_fb))

    def apply(p, x):
        h = x
        for op in plan:
            if op[0] == "conv":
                _, iw, stride = op
                h = common.conv2d(h, p[iw], stride=stride)
            elif op[0] == "block":
                _, in1s, in1b, ic1, in2s, in2b, ic2, isc, stride = op
                pre = jax.nn.relu(common.group_norm(h, p[in1s], p[in1b]))
                out = common.conv2d(pre, p[ic1], stride=stride)
                out = jax.nn.relu(common.group_norm(out, p[in2s], p[in2b]))
                out = common.conv2d(out, p[ic2], stride=1)
                short = h if isc is None else common.conv2d(pre, p[isc], stride=stride)
                h = out + short
            else:  # head
                _, ins, inb, iw, ib = op
                h = jax.nn.relu(common.group_norm(h, p[ins], p[inb]))
                h = h.mean(axis=(1, 2))
                h = common.dense(h, p[iw]) + p[ib]
        return h

    return Model(
        name=name,
        specs=specs,
        apply=apply,
        layer_flops=flops,
        input_sds=jax.ShapeDtypeStruct(
            (batch_size, image_size, image_size, channels), jnp.float32
        ),
        target_sds=jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        task="classify",
        optimizer="sgdm",
        hyper={"weight_decay": 5e-4, "momentum": 0.9, "label_smoothing": 0.1},
    )
