"""LeNet-300-100-style MLP — the paper's Appendix B compression track.

Configurable hidden widths so the same factory also produces the
Small-Dense baselines (a dense network with the sparse network's parameter
count, paper Fig. 2) and the RigL+ restart architectures (Table 2).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from . import common
from .common import Model, ParamSpec


def build(
    name: str = "mlp",
    input_dim: int = 784,
    hidden: Sequence[int] = (300, 100),
    num_classes: int = 10,
    batch_size: int = 128,
    label_smoothing: float = 0.0,
    sparsify_output: bool = False,
) -> Model:
    """Three-layer ReLU MLP. Hidden weights are sparsifiable; the output
    layer follows the paper's Appendix B protocol (kept dense by default).
    """
    dims = [input_dim, *hidden, num_classes]
    specs = []
    flops = []
    nlayers = len(dims) - 1
    for i in range(nlayers):
        is_out = i == nlayers - 1
        specs.append(
            ParamSpec(
                name=f"fc{i + 1}/w",
                shape=(dims[i], dims[i + 1]),
                kind="fc",
                sparsifiable=(not is_out) or sparsify_output,
                # Unlike the conv nets, the LeNet MLP's first layer holds
                # ~88% of the parameters and the paper's Appendix-B track
                # sparsifies it at 99% — no Uniform first-layer exemption.
                first_layer=False,
            )
        )
        flops.append(2.0 * dims[i] * dims[i + 1])
        specs.append(ParamSpec(name=f"fc{i + 1}/b", shape=(dims[i + 1],), kind="bias"))
        flops.append(0.0)

    def apply(params_eff, x):
        h = x
        for i in range(nlayers):
            w, b = params_eff[2 * i], params_eff[2 * i + 1]
            h = common.dense(h, w) + b
            if i != nlayers - 1:
                h = jax.nn.relu(h)
        return h

    return Model(
        name=name,
        specs=specs,
        apply=apply,
        layer_flops=flops,
        input_sds=jax.ShapeDtypeStruct((batch_size, input_dim), jnp.float32),
        target_sds=jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        task="classify",
        optimizer="sgdm",
        hyper={
            "weight_decay": 1e-4,
            "momentum": 0.9,
            "label_smoothing": label_smoothing,
        },
    )
