"""L2 model zoo. Each builder returns a ``common.Model``; the registry in
aot.py maps manifest names to concrete configurations."""

from . import cnn, common, gru, mlp, mobilenet  # noqa: F401
from .common import Model, ParamSpec  # noqa: F401
