"""Shared L2 model machinery: parameter specs, masked layers, normalization.

Every model is a plain-function module over a *flat list* of f32 tensors so
the AOT boundary is trivially flattenable: the rust coordinator sees
``params: [Array; P]`` in the exact order of ``Model.specs`` (recorded in
``artifacts/manifest.txt``) and supplies a same-shaped 0/1 ``mask`` for
each. Non-sparsifiable tensors (biases, norm affines, first layers,
depthwise convs — the paper keeps all of these dense) simply receive
all-ones masks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Sequence

import jax
import jax.numpy as jnp

from .. import kernels

# Parameter kinds. 'fc' = (in, out); 'conv' = (kh, kw, cin, cout);
# 'emb' = (vocab, dim); 'bias'/'norm' = 1-D affines.
KINDS = ("fc", "conv", "emb", "bias", "norm")


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Metadata the coordinator needs for one parameter tensor."""

    name: str
    shape: tuple
    kind: str
    sparsifiable: bool = False
    # Kept dense under the Uniform distribution (paper §3(1): "we keep the
    # first layer dense"); ER/ERK treat it like any other layer.
    first_layer: bool = False

    def __post_init__(self):
        assert self.kind in KINDS, self.kind

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclasses.dataclass
class Model:
    """A lowered-once model: specs + pure apply/loss functions.

    ``apply`` consumes *effective* parameters (already multiplied by the
    mask); the step factories in steps.py own the masking so that
    ``jax.grad`` w.r.t. the raw parameter yields the mask-chained gradient
    and ``jax.grad`` w.r.t. the effective parameter yields the DENSE
    gradient RigL grows from.
    """

    name: str
    specs: List[ParamSpec]
    apply: Callable  # (params_eff, x) -> logits
    input_sds: jax.ShapeDtypeStruct
    target_sds: jax.ShapeDtypeStruct
    task: str = "classify"  # or "lm"
    optimizer: str = "sgdm"  # or "adam"
    hyper: dict = dataclasses.field(default_factory=dict)
    # Dense forward FLOPs attributable to each parameter tensor, per sample
    # (per token for LMs) — the input to the Appendix-H accounting engine
    # on the rust side. Parallel to ``specs``; 0.0 for negligible tensors
    # (biases, norms — the paper omits BN/xent FLOPs too).
    layer_flops: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if not self.layer_flops:
            self.layer_flops = [0.0] * len(self.specs)
        assert len(self.layer_flops) == len(self.specs)

    @property
    def num_params(self) -> int:
        return sum(s.size for s in self.specs)

    def init(self, key: jax.Array) -> List[jax.Array]:
        """He-normal fan-in init for weights, zeros/ones for affines."""
        out = []
        for spec in self.specs:
            key, sub = jax.random.split(key)
            if spec.kind == "fc":
                fan_in = spec.shape[0]
                out.append(
                    jax.random.normal(sub, spec.shape, jnp.float32)
                    * math.sqrt(2.0 / fan_in)
                )
            elif spec.kind == "conv":
                kh, kw, cin, _ = spec.shape
                fan_in = kh * kw * cin
                out.append(
                    jax.random.normal(sub, spec.shape, jnp.float32)
                    * math.sqrt(2.0 / fan_in)
                )
            elif spec.kind == "emb":
                out.append(
                    jax.random.normal(sub, spec.shape, jnp.float32) * 0.1
                )
            elif spec.kind == "norm":
                out.append(jnp.ones(spec.shape, jnp.float32))
            else:  # bias
                out.append(jnp.zeros(spec.shape, jnp.float32))
        return out


# ---------------------------------------------------------------------------
# Masked layers — all matmul-shaped compute routes through the L1 kernel.
# ---------------------------------------------------------------------------


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    """Fully-connected layer over effective (pre-masked) weights.

    The kernel-level mask has already been folded into ``w`` by the step
    factory, so the backend sees an all-ones mask; under the pallas backend
    this still exercises the fused masked-matmul tile schedule.
    """
    return kernels.masked_matmul(x, w, jnp.ones_like(w))


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1, padding: str = "SAME") -> jax.Array:
    """Convolution over effective (pre-masked) weights.

    1×1 (pointwise) convolutions ARE matmuls and route through the L1
    masked-matmul kernel — on MobileNet-style nets that is the dominant
    sparsifiable FLOP sink. k>1 convolutions use ``lax.conv`` over the
    masked weight: the im2col route (patches + L1 matmul) is numerically
    identical (tests/test_models.py pins both against lax.conv) but the
    `conv_general_dilated_patches` lowering becomes a gather that this
    testbed's XLA (xla_extension 0.5.1, CPU) executes ~15× slower than the
    native conv, so the AOT artifacts use the conv lowering; on a real TPU
    the same model definition would tile im2col through the MXU kernel
    (see `conv2d_im2col` and DESIGN.md §Hardware-Adaptation).
    """
    kh, kw, cin, cout = w.shape
    if kh == 1 and kw == 1:
        if stride != 1:
            x = x[:, ::stride, ::stride, :]
        b, oh, ow, _ = x.shape
        y = dense(x.reshape(b * oh * ow, cin), w.reshape(cin, cout))
        return y.reshape(b, oh, ow, cout)
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_im2col(x: jax.Array, w: jax.Array, stride: int = 1, padding: str = "SAME") -> jax.Array:
    """The TPU-shaped path: every conv as a masked matmul on the L1 kernel.

    ``conv_general_dilated_patches`` emits features ordered (cin, kh, kw)
    — verified empirically in tests/test_models.py — so the kernel matrix
    is ``w.transpose(2, 0, 1, 3)``.
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    b, oh, ow, feat = patches.shape
    wm = jnp.transpose(w, (2, 0, 1, 3)).reshape(feat, cout)
    y = dense(patches.reshape(b * oh * ow, feat), wm)
    return y.reshape(b, oh, ow, cout)


def depthwise_conv2d(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Depthwise 3x3 conv (kept dense per the paper's MobileNet protocol).

    w: (kh, kw, C, 1) in the classic depthwise-multiplier layout; HWIO with
    ``feature_group_count=C`` wants (kh, kw, 1, C). Not matmul-shaped, so it
    stays on lax.conv.
    """
    c = x.shape[-1]
    w = jnp.transpose(w, (0, 1, 3, 2))
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        feature_group_count=c,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def group_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, groups: int = 8) -> jax.Array:
    """GroupNorm over NHWC; the BatchNorm substitution (see DESIGN.md §2).

    Normalization affines stay dense, exactly as the paper keeps BN dense.
    """
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(b, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return xg.reshape(b, h, w, c) * scale + bias


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array) -> jax.Array:
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-5) * scale + bias


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def smoothed_xent(logits: jax.Array, y: jax.Array, smoothing: float) -> jax.Array:
    """Label-smoothed softmax cross-entropy, mean over the batch (nats).

    Paper §4.1 uses label smoothing 0.1 for the ImageNet runs.
    """
    k = logits.shape[-1]
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    if smoothing > 0.0:
        uniform = -logp.mean(axis=-1)
        nll = (1.0 - smoothing) * nll + smoothing * uniform
    return nll.mean()


def token_xent(logits: jax.Array, y: jax.Array) -> jax.Array:
    """Per-token cross-entropy, mean over batch×time (nats/char)."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return nll.mean()


def classify_metrics(logits: jax.Array, y: jax.Array):
    """(summed plain cross-entropy, correct-prediction count) for eval."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
    return nll.sum(), correct.sum()


def lm_metrics(logits: jax.Array, y: jax.Array):
    """(summed nats, token count); bits/char = nats·log2(e)/count in rust."""
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    return nll.sum(), jnp.float32(nll.size)
