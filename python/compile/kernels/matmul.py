"""L1 Pallas kernels: tiled (masked) matmul — the compute hot-spot of RigL.

RigL trains with *simulated* sparsity (a 0/1 mask over a dense tensor),
exactly like the reference implementation (github.com/google-research/rigl).
Every dense layer, every im2col'd convolution, and every GRU gate therefore
bottoms out in one primitive: ``y = x @ (w * mask)``.

The kernel tiles for a TPU-like memory hierarchy:

* ``BlockSpec`` expresses the HBM→VMEM schedule: (bm, K) tiles of ``x`` and
  (K, bn) tiles of the masked weight are staged into VMEM and fed to the
  MXU-shaped ``jnp.dot`` with ``preferred_element_type=float32``.
* Block sizes default to 128×128 — the MXU systolic-array shape — and are
  clamped to the problem size. Non-multiple dimensions are zero-padded in
  the wrapper and sliced off afterwards (zero rows/cols contribute nothing
  to the product).
* The mask multiply is fused into the weight tile load, so a production TPU
  build could short-circuit all-zero tiles (block-sparse skip). Under
  ``interpret=True`` (mandatory on CPU PJRT — real TPU lowering emits a
  Mosaic custom-call the CPU plugin cannot execute) the kernel is executed
  as plain HLO, so its *structure* is what we optimize; real-TPU perf is
  estimated analytically in DESIGN.md §Perf / EXPERIMENTS.md §Perf.

``masked_matmul`` carries a ``jax.custom_vjp`` so the backward pass also
flows through the Pallas kernel: dx = g @ (w·m)ᵀ and dw = xᵀ @ g, with the
weight cotangent re-masked (gradients never resurrect pruned weights inside
a training step; RigL's *grow* signal is the separate dense-gradient
artifact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The MXU systolic array is 128x128; VPU lanes are 8x128. 128 is the
# natural tile edge on TPU and a decent cache tile on CPU.
DEFAULT_BLOCK = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (bm, bn) output tile: full-K contraction staged through VMEM."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _masked_matmul_kernel(x_ref, w_ref, m_ref, o_ref):
    """Output tile with the mask multiply fused into the weight-tile load."""
    w = w_ref[...] * m_ref[...]
    o_ref[...] = jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def _ceil_to(v: int, b: int) -> int:
    return ((v + b - 1) // b) * b


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def mm(x: jax.Array, w: jax.Array, *, bm: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK) -> jax.Array:
    """Tiled ``x @ w`` through the Pallas kernel (f32, 2-D operands)."""
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0], (
        f"mm shape mismatch: {x.shape} @ {w.shape}"
    )
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = _pad_to(x.astype(jnp.float32), mp, k)
    wp = _pad_to(w.astype(jnp.float32), k, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp)
    return out[:m, :n]


def _mm_masked(x: jax.Array, w: jax.Array, mask: jax.Array, bm: int, bn: int) -> jax.Array:
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = _pad_to(x.astype(jnp.float32), mp, k)
    wp = _pad_to(w.astype(jnp.float32), k, np_)
    mp_ = _pad_to(mask.astype(jnp.float32), k, np_)
    out = pl.pallas_call(
        _masked_matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, mp_)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def masked_matmul(x, w, mask, bm: int = DEFAULT_BLOCK, bn: int = DEFAULT_BLOCK):
    """``x @ (w * mask)`` with both passes routed through the Pallas kernel.

    mask is a 0/1 float tensor with ``w``'s shape; its cotangent is zero
    (topology is coordinator state, not a trained quantity).
    """
    return _mm_masked(x, w, mask, bm, bn)


def _masked_matmul_fwd(x, w, mask, bm, bn):
    y = _mm_masked(x, w, mask, bm, bn)
    return y, (x, w, mask)


def _masked_matmul_bwd(bm, bn, res, g):
    x, w, mask = res
    wm = w * mask
    dx = mm(g, wm.T, bm=bm, bn=bn)
    # Re-mask the weight cotangent: within a step pruned weights stay frozen.
    dw = mm(x.T, g, bm=bm, bn=bn) * mask
    return dx, dw, jnp.zeros_like(mask)


masked_matmul.defvjp(_masked_matmul_fwd, _masked_matmul_bwd)


def vmem_bytes(bm: int, bn: int, k: int, itemsize: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (x-tile + w-tile + m-tile + o-tile).

    Used by the §Perf analysis: VMEM on TPUv4 is 16 MiB/core, so valid block
    shapes must keep this under budget with double-buffering (×2).
    """
    return itemsize * (bm * k + 2 * k * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int, bn: int) -> float:
    """Fraction of MXU-issued MACs that are useful (not padding).

    The padded problem is ceil(m/bm)·bm × ceil(n/bn)·bn; utilization is the
    ratio of true MACs to padded MACs. 1.0 means perfectly tiled.
    """
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    return (m * n * k) / float(mp * np_ * k)
