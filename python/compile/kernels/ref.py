"""Pure-jnp oracles for the L1 Pallas kernels.

These are the CORE correctness signal: pytest (and hypothesis sweeps)
assert the Pallas kernels match these references to float32 tolerance over
randomized shapes/values. The production jnp backend of the models also
routes through these so the ``--backend jnp`` and ``--backend pallas``
artifacts are semantically identical programs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BIG = 1e30


def mm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Plain f32 matmul."""
    return jnp.dot(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def masked_matmul_ref(x: jax.Array, w: jax.Array, mask: jax.Array) -> jax.Array:
    """``x @ (w * mask)``; differentiable in x and w (mask is constant-like)."""
    return mm_ref(x, w * mask)


def rigl_scores_ref(w: jax.Array, g: jax.Array, mask: jax.Array):
    """Drop/grow scores; see kernels/scores.py for the conventions."""
    m = mask.astype(jnp.float32)
    inv = 1.0 - m
    drop = jnp.abs(w) * m + inv * BIG
    grow = jnp.abs(g) * inv - m * BIG
    return drop, grow
