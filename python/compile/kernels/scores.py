"""L1 Pallas kernel: RigL drop/grow score computation.

Every ΔT steps RigL updates the topology of each layer:

* drop the k smallest-|θ| *active* connections:
  ``ArgTopK(-|θ|, k)`` over the active set;
* grow the k largest-|∇_Θ L| *inactive* connections:
  ``ArgTopK(|∇_Θ L|, k)`` over the complement of the post-drop active set.

Selection (ArgTopK) is coordinator logic and lives in Rust
(`rust/src/topology/`); this kernel computes the *scores* the coordinator
sorts, fused elementwise over the flattened tensors so the dense gradient
can be consumed tile-by-tile and discarded — the paper's point that RigL
never needs to *store* dense state, only stream it (§3(4)).

Conventions (BIG sentinel = 1e30):

* ``drop_score  = |θ|·m + (1-m)·BIG``  → the k *smallest* are dropped;
  inactive entries are pushed to +BIG so they are never selected.
* ``grow_score  = |g|·(1-m) - m·BIG``  → the k *largest* are grown;
  active entries are pushed to -BIG so they are never re-grown.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30
_BLOCK = 4096


def _scores_kernel(w_ref, g_ref, m_ref, drop_ref, grow_ref):
    w = w_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    inv = 1.0 - m
    drop_ref[...] = jnp.abs(w) * m + inv * BIG
    grow_ref[...] = jnp.abs(g) * inv - m * BIG


def _pad1(x: jax.Array, n: int) -> jax.Array:
    return jnp.pad(x, (0, n - x.shape[0])) if n != x.shape[0] else x


@functools.partial(jax.jit, static_argnames=("block",))
def rigl_scores(w: jax.Array, g: jax.Array, mask: jax.Array, *, block: int = _BLOCK):
    """Return ``(drop_score, grow_score)`` flattened to ``w``'s shape.

    ``w``: current weights; ``g``: dense gradient ∇_Θ L (same shape);
    ``mask``: 0/1 float activity mask.
    """
    shape = w.shape
    wf = w.reshape(-1).astype(jnp.float32)
    gf = g.reshape(-1).astype(jnp.float32)
    mf = mask.reshape(-1).astype(jnp.float32)
    n = wf.shape[0]
    block = min(block, n)
    npad = ((n + block - 1) // block) * block
    wf, gf, mf = _pad1(wf, npad), _pad1(gf, npad), _pad1(mf, npad)
    # Padding has m=0 ⇒ drop_score=BIG (never dropped); grow_score=0 which
    # could collide with real zeros, so the wrapper slices padding off
    # before the coordinator ever sees it.
    drop, grow = pl.pallas_call(
        _scores_kernel,
        grid=(npad // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((npad,), jnp.float32)] * 2,
        interpret=True,
    )(wf, gf, mf)
    return drop[:n].reshape(shape), grow[:n].reshape(shape)
