"""L1 Pallas kernels for RigL's compute hot-spots, plus pure-jnp oracles.

The active backend is selected at AOT time (``aot.py --backend``):

* ``jnp``    — the reference path; XLA-CPU fuses it to fast native GEMMs.
               This is the default for the runtime artifacts on this
               CPU-PJRT testbed.
* ``pallas`` — the TPU-shaped tiled kernels under ``interpret=True``; this
               is the path a real TPU deployment would compile, and it is
               what pytest verifies against the oracles and what the rust
               integration tests execute end-to-end for the MLP artifacts.
"""

from . import matmul, ref, scores  # noqa: F401

_BACKEND = "jnp"


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("jnp", "pallas"):
        raise ValueError(f"unknown kernel backend {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def masked_matmul(x, w, mask):
    """Backend-dispatching ``x @ (w * mask)`` — the universal hot path."""
    if _BACKEND == "pallas":
        return matmul.masked_matmul(x, w, mask)
    return ref.masked_matmul_ref(x, w, mask)


def rigl_scores(w, g, mask):
    """Backend-dispatching drop/grow score computation."""
    if _BACKEND == "pallas":
        return scores.rigl_scores(w, g, mask)
    return ref.rigl_scores_ref(w, g, mask)
