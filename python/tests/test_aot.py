"""AOT pipeline integrity: HLO text is parseable-looking, the manifest
matches the lowered programs, and jnp/pallas artifacts agree numerically
at the step level (not just the layer level)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, kernels, steps
from compile.models import mlp


@pytest.fixture(scope="module")
def tiny():
    return mlp.build("tiny", input_dim=6, hidden=(5, 4), num_classes=3, batch_size=2)


def test_to_hlo_text_shape(tiny):
    lowered = jax.jit(steps.make_eval_step(tiny)).lower(*steps.eval_input_sds(tiny))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True: the root must be a tuple of the 2 eval outputs.
    assert "(f32[], f32[])" in text.replace(" ", "")[:20000] or "tuple" in text


def test_lower_model_writes_all(tmp_path, tiny):
    lines = aot.lower_model(tiny, str(tmp_path), "jnp")
    files = sorted(os.listdir(tmp_path))
    assert files == [
        "tiny_densegrad.hlo.txt",
        "tiny_eval.hlo.txt",
        "tiny_train.hlo.txt",
    ]
    assert lines[0] == "model tiny"
    assert lines[-1] == "end"
    params = [ln for ln in lines if ln.startswith("param ")]
    assert len(params) == len(tiny.specs)
    # param line format: name kind sparsifiable first_layer flops dims...
    # (the MLP opts out of the Uniform first-layer exemption: flag = 0).
    first = params[0].split()
    assert first[1:5] == ["fc1/w", "fc", "1", "0"]
    assert float(first[5]) == 2.0 * 6 * 5
    assert first[6:] == ["6", "5"]


def test_manifest_hyper_lines(tmp_path, tiny):
    lines = aot.lower_model(tiny, str(tmp_path), "jnp")
    hyper = {ln.split()[1]: float(ln.split()[2]) for ln in lines if ln.startswith("hyper ")}
    assert hyper["momentum"] == 0.9
    assert hyper["weight_decay"] == pytest.approx(1e-4)


def test_registry_builders_all_construct():
    for name, builder in aot.REGISTRY.items():
        model = builder()
        assert model.name == name
        assert model.num_params > 0


def test_backend_step_equivalence(tiny):
    """Full train-step outputs must agree between jnp and pallas backends —
    the guarantee that lets the runtime default to the fast jnp artifacts
    while the pallas path is the TPU-shaped reference."""
    P = len(tiny.specs)
    masks = []
    for i, s in enumerate(tiny.specs):
        if s.sparsifiable:
            m = jax.random.uniform(jax.random.PRNGKey(i), s.shape) < 0.5
            masks.append(m.astype(jnp.float32))
        else:
            masks.append(jnp.ones(s.shape, jnp.float32))
    params = [p * m for p, m in zip(tiny.init(jax.random.PRNGKey(0)), masks)]
    mom = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(jax.random.PRNGKey(1), tiny.input_sds.shape, jnp.float32)
    y = jnp.array([0, 2], jnp.int32)

    outs = {}
    for backend in ("jnp", "pallas"):
        kernels.set_backend(backend)
        train = steps.make_train_step(tiny)
        outs[backend] = train(*params, *mom, *masks, x, y, jnp.float32(0.1))
    kernels.set_backend("jnp")
    for a, b in zip(outs["jnp"], outs["pallas"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_sds_line_format(tiny):
    assert aot._sds_line("input", tiny.input_sds) == "input f32 2 6"
    assert aot._sds_line("target", tiny.target_sds) == "target i32 2"
