"""L2 model correctness: layers vs lax oracles, spec/shape integrity,
backend (jnp vs pallas) equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels
from compile.models import cnn, common, gru, mlp, mobilenet


@pytest.fixture(autouse=True)
def _jnp_backend():
    kernels.set_backend("jnp")
    yield
    kernels.set_backend("jnp")


def _ones_masks(model):
    return [jnp.ones(s.shape, jnp.float32) for s in model.specs]


# ---------------------------------------------------------------------------
# Layer oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride,cin,cout,kh", [(1, 3, 8, 3), (2, 4, 6, 3), (1, 5, 7, 1), (2, 8, 8, 1)])
@pytest.mark.parametrize("impl", [common.conv2d, common.conv2d_im2col])
def test_conv2d_matches_lax(impl, stride, cin, cout, kh):
    # The production conv2d and the TPU-shaped im2col path must both pin
    # to the lax.conv oracle (1x1 strided convs exercise the pointwise
    # masked-matmul branch of conv2d).
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, cin), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (kh, kh, cin, cout), jnp.float32)
    got = impl(x, w, stride=stride)
    want = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_im2col_matches_lax_pallas_backend():
    kernels.set_backend("pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 3), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 8), jnp.float32)
    want = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(common.conv2d_im2col(x, w), want, rtol=1e-4, atol=1e-4)


def test_depthwise_conv_matches_grouped_lax():
    c = 6
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, c), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, c, 1), jnp.float32)
    got = common.depthwise_conv2d(x, w, stride=2)
    want = jax.lax.conv_general_dilated(
        x,
        jnp.transpose(w, (0, 1, 3, 2)),
        (2, 2),
        "SAME",
        feature_group_count=c,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_group_norm_normalizes():
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 5, 5, 16), jnp.float32) * 7 + 3
    y = common.group_norm(x, jnp.ones((16,)), jnp.zeros((16,)), groups=8)
    # Per-sample, per-group statistics should be ~N(0,1).
    yg = np.asarray(y).reshape(3, 5, 5, 8, 2)
    np.testing.assert_allclose(yg.mean(axis=(1, 2, 4)), 0.0, atol=1e-4)
    np.testing.assert_allclose(yg.var(axis=(1, 2, 4)), 1.0, atol=1e-2)


def test_group_norm_handles_non_divisible_channels():
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, 4, 10), jnp.float32)
    y = common.group_norm(x, jnp.ones((10,)), jnp.zeros((10,)), groups=8)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()


def test_smoothed_xent_reduces_to_plain():
    logits = jax.random.normal(jax.random.PRNGKey(6), (4, 10), jnp.float32)
    y = jnp.array([0, 3, 9, 2], jnp.int32)
    plain = common.smoothed_xent(logits, y, 0.0)
    logp = jax.nn.log_softmax(logits)
    want = -np.mean([logp[i, y[i]] for i in range(4)])
    np.testing.assert_allclose(plain, want, rtol=1e-6)
    # Smoothing strictly increases loss for a confident correct model.
    conf = jnp.eye(10)[y] * 20.0
    assert common.smoothed_xent(conf, y, 0.1) > common.smoothed_xent(conf, y, 0.0)


def test_classify_metrics_counts():
    logits = jnp.array([[2.0, 0.0], [0.0, 2.0], [2.0, 0.0]])
    y = jnp.array([0, 1, 1], jnp.int32)
    s, c = common.classify_metrics(logits, y)
    assert float(c) == 2.0
    assert float(s) > 0.0


def test_lm_metrics_token_count():
    logits = jax.random.normal(jax.random.PRNGKey(7), (2, 5, 11), jnp.float32)
    y = jnp.zeros((2, 5), jnp.int32)
    s, c = common.lm_metrics(logits, y)
    assert float(c) == 10.0


# ---------------------------------------------------------------------------
# Model integrity
# ---------------------------------------------------------------------------

BUILDERS = {
    "mlp": lambda: mlp.build(batch_size=4),
    "cnn": lambda: cnn.build(depth=10, width=1.0, batch_size=2, image_size=16),
    "wrn": lambda: cnn.build(depth=16, width=2.0, batch_size=2, image_size=16),
    "mobilenet": lambda: mobilenet.build(batch_size=2, image_size=16),
    "gru": lambda: gru.build(batch_size=2, seq_len=8, state=32, emb=16, readouts=(16, 8)),
}


@pytest.mark.parametrize("name", sorted(BUILDERS))
def test_init_matches_specs(name):
    model = BUILDERS[name]()
    params = model.init(jax.random.PRNGKey(0))
    assert len(params) == len(model.specs)
    for p, s in zip(params, model.specs):
        assert p.shape == s.shape, s.name
    assert model.num_params == sum(int(np.prod(s.shape)) for s in model.specs)


@pytest.mark.parametrize("name", sorted(BUILDERS))
def test_apply_shape_and_finite(name):
    model = BUILDERS[name]()
    params = model.init(jax.random.PRNGKey(0))
    if model.task == "lm":
        x = jnp.zeros(model.input_sds.shape, jnp.int32)
        logits = model.apply(params, x)
        assert logits.shape == (*model.input_sds.shape, model.specs[0].shape[0])
    else:
        x = jnp.ones(model.input_sds.shape, jnp.float32)
        logits = model.apply(params, x)
        assert logits.shape[0] == model.input_sds.shape[0]
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("name", sorted(BUILDERS))
def test_every_model_has_sparsifiable_and_first_layer(name):
    model = BUILDERS[name]()
    assert any(s.sparsifiable for s in model.specs)
    # The MLP opts out of the Uniform first-layer exemption (Appendix B
    # sparsifies its first layer at 99%); all other models mark exactly one.
    expected = 0 if name == "mlp" else 1
    assert sum(s.first_layer for s in model.specs) == expected


def test_mobilenet_depthwise_kept_dense():
    model = BUILDERS["mobilenet"]()
    for s in model.specs:
        if "/dw/" in s.name or s.name.startswith("stem"):
            assert not s.sparsifiable, s.name


def test_masking_zeroes_contributions():
    """With all sparsifiable weights masked out, the MLP must output bias-only."""
    model = BUILDERS["mlp"]()
    params = model.init(jax.random.PRNGKey(0))
    masks = []
    for s in model.specs:
        masks.append(jnp.zeros(s.shape) if s.sparsifiable else jnp.ones(s.shape))
    eff = [p * m for p, m in zip(params, masks)]
    x = jax.random.normal(jax.random.PRNGKey(1), model.input_sds.shape)
    out = model.apply(eff, x)
    # Output layer weights are dense (not sparsifiable) but their input is
    # bias-fed only, so all rows must be identical.
    o = np.asarray(out)
    np.testing.assert_allclose(o, np.broadcast_to(o[0], o.shape), rtol=1e-5, atol=1e-6)


def test_mlp_backend_equivalence():
    """jnp and pallas artifacts must be the same program numerically."""
    model = BUILDERS["mlp"]()
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), model.input_sds.shape)
    kernels.set_backend("jnp")
    out_jnp = model.apply(params, x)
    kernels.set_backend("pallas")
    out_pallas = model.apply(params, x)
    np.testing.assert_allclose(out_jnp, out_pallas, rtol=1e-4, atol=1e-4)


def test_gru_causality():
    """Changing a late token must not affect earlier logits."""
    model = BUILDERS["gru"]()
    params = model.init(jax.random.PRNGKey(0))
    x1 = jnp.zeros((2, 8), jnp.int32)
    x2 = x1.at[:, 7].set(3)
    l1 = model.apply(params, x1)
    l2 = model.apply(params, x2)
    np.testing.assert_allclose(l1[:, :7], l2[:, :7], rtol=1e-5, atol=1e-6)
    assert not np.allclose(l1[:, 7], l2[:, 7])
