"""L1 kernel correctness: Pallas vs pure-jnp oracle.

This is the core correctness signal for the compute layer — hypothesis
sweeps shapes and values, asserting allclose against ref.py for forward
AND backward passes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref, scores


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# mm: plain tiled matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (2, 3, 4),
        (8, 8, 8),
        (128, 128, 128),
        (129, 64, 130),  # non-multiple of block in both tile dims
        (300, 784, 100),  # the LeNet-300-100 shapes
        (7, 257, 13),
    ],
)
def test_mm_matches_ref(m, k, n):
    x, w = _rand(m * 1000 + n, m, k), _rand(k * 1000 + n, k, n)
    np.testing.assert_allclose(
        matmul.mm(x, w), ref.mm_ref(x, w), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 80),
    n=st.integers(1, 80),
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mm_hypothesis(m, k, n, bm, bn, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    got = matmul.mm(x, w, bm=bm, bn=bn)
    np.testing.assert_allclose(got, ref.mm_ref(x, w), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# masked_matmul: forward + custom VJP
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,density", [(16, 32, 24, 0.1), (64, 128, 32, 0.5), (5, 7, 3, 0.9)])
def test_masked_matmul_forward(m, k, n, density):
    x, w = _rand(1, m, k), _rand(2, k, n)
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (k, n)) < density).astype(jnp.float32)
    np.testing.assert_allclose(
        matmul.masked_matmul(x, w, mask),
        ref.masked_matmul_ref(x, w, mask),
        rtol=1e-5,
        atol=1e-5,
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 60),
    n=st.integers(1, 40),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_matmul_vjp_hypothesis(m, k, n, density, seed):
    """The pallas custom VJP must match jnp autodiff of the oracle."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(keys[0], (m, k), jnp.float32)
    w = jax.random.normal(keys[1], (k, n), jnp.float32)
    mask = (jax.random.uniform(keys[2], (k, n)) < density).astype(jnp.float32)
    g = jax.random.normal(keys[3], (m, n), jnp.float32)

    def f_pallas(x, w):
        return jnp.sum(matmul.masked_matmul(x, w, mask) * g)

    def f_ref(x, w):
        return jnp.sum(ref.masked_matmul_ref(x, w, mask) * g)

    dx_p, dw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    dx_r, dw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(dx_p, dx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw_p, dw_r, rtol=1e-4, atol=1e-4)


def test_masked_matmul_weight_cotangent_is_masked():
    """Gradients must never resurrect pruned weights within a step."""
    x, w = _rand(4, 8, 16), _rand(5, 16, 8)
    mask = (jax.random.uniform(jax.random.PRNGKey(6), (16, 8)) < 0.3).astype(jnp.float32)
    dw = jax.grad(lambda w: jnp.sum(matmul.masked_matmul(x, w, mask)))(w)
    assert np.all(np.asarray(dw)[np.asarray(mask) == 0.0] == 0.0)


def test_masked_matmul_zero_mask_zero_output():
    x, w = _rand(7, 4, 4), _rand(8, 4, 4)
    out = matmul.masked_matmul(x, w, jnp.zeros((4, 4), jnp.float32))
    np.testing.assert_array_equal(out, np.zeros((4, 4), np.float32))


# ---------------------------------------------------------------------------
# rigl_scores
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5000),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_scores_hypothesis(n, density, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(keys[0], (n,), jnp.float32)
    g = jax.random.normal(keys[1], (n,), jnp.float32)
    m = (jax.random.uniform(keys[2], (n,)) < density).astype(jnp.float32)
    drop_p, grow_p = scores.rigl_scores(w, g, m)
    drop_r, grow_r = ref.rigl_scores_ref(w, g, m)
    np.testing.assert_allclose(drop_p, drop_r, rtol=1e-6)
    np.testing.assert_allclose(grow_p, grow_r, rtol=1e-6)


def test_scores_semantics():
    """Active entries are never grown; inactive entries are never dropped."""
    w = jnp.array([1.0, -2.0, 0.0, 3.0])
    g = jnp.array([10.0, -20.0, 30.0, 40.0])
    m = jnp.array([1.0, 1.0, 0.0, 0.0])
    drop, grow = scores.rigl_scores(w, g, m)
    # Active: drop score = |w|; inactive: pushed to +BIG.
    np.testing.assert_allclose(np.asarray(drop)[:2], [1.0, 2.0])
    assert np.all(np.asarray(drop)[2:] >= scores.BIG * 0.99)
    # Inactive: grow score = |g|; active: pushed to -BIG.
    np.testing.assert_allclose(np.asarray(grow)[2:], [30.0, 40.0])
    assert np.all(np.asarray(grow)[:2] <= -scores.BIG * 0.99)


def test_scores_2d_shape_preserved():
    w = _rand(11, 13, 7)
    g = _rand(12, 13, 7)
    m = jnp.ones((13, 7), jnp.float32)
    drop, grow = scores.rigl_scores(w, g, m)
    assert drop.shape == (13, 7) and grow.shape == (13, 7)


# ---------------------------------------------------------------------------
# Analytic TPU-perf helpers (§Perf)
# ---------------------------------------------------------------------------


def test_vmem_bytes_fits_tpu_budget():
    # Default 128x128 blocks with the largest K in the model zoo (im2col'd
    # WRN conv: K = 3*3*128 = 1152) must fit VMEM with double buffering.
    b = matmul.vmem_bytes(128, 128, 1152)
    assert 2 * b < 16 * 1024 * 1024


def test_mxu_utilization_bounds():
    assert matmul.mxu_utilization(128, 128, 64, 128, 128) == 1.0
    u = matmul.mxu_utilization(129, 1, 64, 128, 128)
    assert 0.0 < u < 0.01 or u <= 1.0
    assert matmul.mxu_utilization(300, 100, 784, 128, 128) == pytest.approx(
        (300 * 100) / (384 * 128), rel=1e-9
    )
