"""Step-factory semantics: optimizer math vs numpy references, masking
invariants, dense-gradient (grow-signal) behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import steps
from compile.models import gru, mlp


@pytest.fixture(scope="module")
def tiny_mlp():
    return mlp.build("tiny", input_dim=12, hidden=(8, 6), num_classes=4, batch_size=5)


@pytest.fixture(scope="module")
def tiny_gru():
    return gru.build("tgru", vocab=11, emb=6, state=8, readouts=(8, 6), seq_len=7, batch_size=3)


def _masks(model, density=0.5, seed=9):
    ms = []
    for i, s in enumerate(model.specs):
        if s.sparsifiable:
            m = (jax.random.uniform(jax.random.PRNGKey(seed + i), s.shape) < density)
            ms.append(m.astype(jnp.float32))
        else:
            ms.append(jnp.ones(s.shape, jnp.float32))
    return ms


def _batch(model, seed=0):
    if model.task == "lm":
        x = jax.random.randint(jax.random.PRNGKey(seed), model.input_sds.shape, 0, model.specs[0].shape[0])
        y = jax.random.randint(jax.random.PRNGKey(seed + 1), model.target_sds.shape, 0, model.specs[0].shape[0])
        return x.astype(jnp.int32), y.astype(jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(seed), model.input_sds.shape, jnp.float32)
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), model.target_sds.shape, 0, 4)
    return x, y.astype(jnp.int32)


# ---------------------------------------------------------------------------
# SGD + momentum
# ---------------------------------------------------------------------------


def test_sgdm_matches_numpy_reference(tiny_mlp):
    """One train step == hand-rolled heavy-ball SGD on masked gradients."""
    model = tiny_mlp
    P = len(model.specs)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(0)), _masks(model))]
    masks = _masks(model)
    params = [p * m for p, m in zip(params, masks)]
    mom = [jnp.zeros_like(p) for p in params]
    x, y = _batch(model)
    lr = jnp.float32(0.2)

    # Reference masked gradient via jax autodiff of the same loss.
    def loss_fn(ps):
        eff = [q * m for q, m in zip(ps, masks)]
        logits = model.apply(eff, x)
        return steps._loss(model, logits, y)

    ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params)

    train = steps.make_train_step(model)
    out = train(*params, *mom, *masks, x, y, lr)
    new_p, new_m, loss = out[:P], out[P : 2 * P], out[-1]
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)

    wd, mu = model.hyper["weight_decay"], model.hyper["momentum"]
    for q, g, v, m, np_, nm in zip(params, ref_grads, mom, masks, new_p, new_m):
        gg = np.asarray(g) + wd * np.asarray(q)
        v2 = mu * np.asarray(v) + gg
        want_m = v2 * np.asarray(m)
        want_p = (np.asarray(q) - 0.2 * v2) * np.asarray(m)
        np.testing.assert_allclose(np.asarray(nm), want_m, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(np_), want_p, rtol=1e-5, atol=1e-6)


def test_sgdm_masking_invariant(tiny_mlp):
    """Pruned coordinates stay exactly zero through many steps."""
    model = tiny_mlp
    P = len(model.specs)
    masks = _masks(model, density=0.3)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(1)), masks)]
    mom = [jnp.zeros_like(p) for p in params]
    train = steps.make_train_step(model)
    for step in range(5):
        x, y = _batch(model, seed=step)
        out = train(*params, *mom, *masks, x, y, jnp.float32(0.1))
        params, mom = list(out[:P]), list(out[P : 2 * P])
    for q, v, m in zip(params, mom, masks):
        mm = np.asarray(m)
        assert np.all(np.asarray(q)[mm == 0] == 0.0)
        assert np.all(np.asarray(v)[mm == 0] == 0.0)


def test_sgdm_loss_decreases(tiny_mlp):
    """A few steps on a fixed batch must reduce the loss (optimization sanity)."""
    model = tiny_mlp
    P = len(model.specs)
    masks = _masks(model, density=0.5)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(2)), masks)]
    mom = [jnp.zeros_like(p) for p in params]
    train = jax.jit(steps.make_train_step(model))
    x, y = _batch(model)
    losses = []
    for _ in range(30):
        out = train(*params, *mom, *masks, x, y, jnp.float32(0.3))
        params, mom = list(out[:P]), list(out[P : 2 * P])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.8, losses


# ---------------------------------------------------------------------------
# Adam (GRU)
# ---------------------------------------------------------------------------


def test_adam_matches_numpy_reference(tiny_gru):
    model = tiny_gru
    P = len(model.specs)
    masks = _masks(model, density=0.6)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(3)), masks)]
    m1 = [jnp.zeros_like(p) for p in params]
    m2 = [jnp.zeros_like(p) for p in params]
    t = jnp.float32(0.0)
    x, y = _batch(model)
    lr = jnp.float32(1e-3)

    def loss_fn(ps):
        eff = [q * m for q, m in zip(ps, masks)]
        return steps._loss(model, model.apply(eff, x), y)

    ref_loss, ref_grads = jax.value_and_grad(loss_fn)(params)
    ref_grads = steps._clip_by_global_norm(ref_grads, model.hyper["grad_clip"])

    train = steps.make_train_step(model)
    out = train(*params, *m1, *m2, t, *masks, x, y, lr)
    new_p, new_t, loss = out[:P], out[3 * P], out[-1]
    np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)
    assert float(new_t) == 1.0

    b1, b2, eps = model.hyper["b1"], model.hyper["b2"], model.hyper["eps"]
    wd = model.hyper["weight_decay"]
    for q, g, m, np_ in zip(params, ref_grads, masks, new_p):
        gg = np.asarray(g) + wd * np.asarray(q)
        a2 = (1 - b1) * gg
        v2 = (1 - b2) * gg * gg
        ahat = a2 / (1 - b1**1)
        vhat = v2 / (1 - b2**1)
        want = (np.asarray(q) - 1e-3 * ahat / (np.sqrt(vhat) + eps)) * np.asarray(m)
        np.testing.assert_allclose(np.asarray(np_), want, rtol=1e-4, atol=1e-6)


def test_adam_time_counter_advances(tiny_gru):
    model = tiny_gru
    P = len(model.specs)
    masks = _masks(model)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(4)), masks)]
    m1 = [jnp.zeros_like(p) for p in params]
    m2 = [jnp.zeros_like(p) for p in params]
    train = jax.jit(steps.make_train_step(model))
    x, y = _batch(model)
    t = jnp.float32(0.0)
    for i in range(3):
        out = train(*params, *m1, *m2, t, *masks, x, y, jnp.float32(1e-3))
        params = list(out[:P])
        m1, m2, t = list(out[P : 2 * P]), list(out[2 * P : 3 * P]), out[3 * P]
        assert float(t) == i + 1


# ---------------------------------------------------------------------------
# Dense gradient (grow signal)
# ---------------------------------------------------------------------------


def test_densegrad_nonzero_on_inactive(tiny_mlp):
    """RigL's whole point: ∇_Θ L is informative on INACTIVE connections."""
    model = tiny_mlp
    P = len(model.specs)
    masks = _masks(model, density=0.3)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(5)), masks)]
    x, y = _batch(model)
    dg = steps.make_dense_grad(model)
    out = dg(*params, *masks, x, y)
    sparse_specs = [s for s in model.specs if s.sparsifiable]
    S = len(sparse_specs)
    dense_grads, scores_, loss = out[:S], out[S : 2 * S], out[-1]
    assert float(loss) > 0
    inactive_mag = 0.0
    for g, m in zip(dense_grads, (m for m, s in zip(masks, model.specs) if s.sparsifiable)):
        gm = np.asarray(g)[np.asarray(m) == 0]
        inactive_mag += float(np.abs(gm).sum())
    assert inactive_mag > 0.0, "dense grads must reach pruned coordinates"


def test_densegrad_scores_match_convention(tiny_mlp):
    model = tiny_mlp
    masks = _masks(model, density=0.4)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(6)), masks)]
    x, y = _batch(model)
    out = steps.make_dense_grad(model)(*params, *masks, x, y)
    sparse = [(i, s) for i, s in enumerate(model.specs) if s.sparsifiable]
    S = len(sparse)
    for k, (i, s) in enumerate(sparse):
        grow = np.asarray(out[S + k])
        m = np.asarray(masks[i])
        assert np.all(grow[m == 1.0] <= -1e29), "active entries must never grow"
        g = np.asarray(out[k])
        np.testing.assert_allclose(grow[m == 0.0], np.abs(g)[m == 0.0], rtol=1e-5)


def test_densegrad_consistent_with_train_grad(tiny_mlp):
    """dense_grad · mask == the masked gradient the train step applies."""
    model = tiny_mlp
    masks = _masks(model, density=0.5)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(7)), masks)]
    x, y = _batch(model)
    out = steps.make_dense_grad(model)(*params, *masks, x, y)

    def loss_fn(ps):
        eff = [q * m for q, m in zip(ps, masks)]
        return steps._loss(model, model.apply(eff, x), y)

    masked_grads = jax.grad(loss_fn)(params)
    k = 0
    for i, s in enumerate(model.specs):
        if not s.sparsifiable:
            continue
        np.testing.assert_allclose(
            np.asarray(out[k]) * np.asarray(masks[i]),
            np.asarray(masked_grads[i]),
            rtol=1e-4,
            atol=1e-6,
        )
        k += 1


# ---------------------------------------------------------------------------
# Eval
# ---------------------------------------------------------------------------


def test_eval_step_classify(tiny_mlp):
    model = tiny_mlp
    masks = _masks(model)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(8)), masks)]
    x, y = _batch(model)
    s, c = steps.make_eval_step(model)(*params, *masks, x, y)
    assert 0.0 <= float(c) <= x.shape[0]
    assert float(s) > 0.0


def test_eval_step_lm_counts_tokens(tiny_gru):
    model = tiny_gru
    masks = _masks(model)
    params = [p * m for p, m in zip(model.init(jax.random.PRNGKey(9)), masks)]
    x, y = _batch(model)
    s, c = steps.make_eval_step(model)(*params, *masks, x, y)
    assert float(c) == float(np.prod(model.input_sds.shape))


def test_grad_clip_bounds_global_norm():
    gs = [jnp.full((10,), 100.0), jnp.full((5,), -100.0)]
    clipped = steps._clip_by_global_norm(gs, 1.0)
    total = np.sqrt(sum(float(jnp.sum(g * g)) for g in clipped))
    assert total <= 1.0 + 1e-5
    # Small gradients pass through untouched.
    gs2 = [jnp.full((4,), 1e-3)]
    np.testing.assert_allclose(steps._clip_by_global_norm(gs2, 1.0)[0], gs2[0], rtol=1e-6)


def test_io_arity_contract(tiny_mlp, tiny_gru):
    """The manifest I/O contract the rust side depends on."""
    P = len(tiny_mlp.specs)
    assert len(steps.train_input_sds(tiny_mlp)) == 3 * P + 3
    assert len(steps.densegrad_input_sds(tiny_mlp)) == 2 * P + 2
    Pg = len(tiny_gru.specs)
    assert len(steps.train_input_sds(tiny_gru)) == 4 * Pg + 4
