//! End-to-end validation driver (DESIGN.md §5): the full three-layer stack
//! on the largest model in the zoo.
//!
//!     cargo run --release --example e2e_sparse_training [steps] [sparsity]
//!
//! Trains a ~0.7M-parameter WRN-16-2 (the paper's CIFAR-10 architecture
//! scaled to the CPU testbed) with RigL-ERK on the synthetic image
//! workload for a few hundred steps, logging the loss curve, running the
//! dense and static baselines for comparison, and checkpointing the sparse
//! solution. The run recorded in EXPERIMENTS.md §E2E came from this
//! binary.

use anyhow::Result;
use rigl::model::{load_manifest, save_checkpoint, Checkpoint};
use rigl::sparsity::Distribution;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let sparsity: f64 = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(0.9);

    let rt = Runtime::cpu()?;
    let manifest = load_manifest(&rigl::artifacts_dir())?;

    let mut cfg = TrainConfig::new("wrn", Method::Rigl);
    cfg.sparsity = sparsity;
    cfg.distribution = Distribution::Erk;
    cfg.steps = steps;
    cfg.delta_t = (steps / 8).max(10);
    cfg.eval_every = (steps / 6).max(1);

    let trainer = Trainer::new(&rt, &manifest, &cfg)?;
    println!(
        "== e2e: WRN-16-2 ({} params), RigL-ERK S={sparsity}, {steps} steps ==",
        trainer.def.num_params()
    );

    // RigL run with full logging.
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state)?;
    println!("\n-- loss curve (every 10 steps) --");
    for (t, loss) in &r.loss_history {
        println!("step {t:>6}  train loss {loss:.4}");
    }
    println!("\n-- eval curve --");
    for (t, m) in &r.eval_history {
        println!("step {t:>6}  val acc {m:.4}");
    }
    println!(
        "\nRigL(ERK): acc {:.4} | trainFLOPs {:.3}x | testFLOPs {:.3}x | S={:.4} | {:.1}s",
        r.final_metric, r.train_flops_ratio, r.test_flops_ratio, r.final_sparsity, r.wall_seconds
    );

    // Checkpoint the sparse solution (params + masks + momentum).
    let ckpt_path = std::env::temp_dir().join("rigl_e2e_wrn.ckpt");
    save_checkpoint(
        &ckpt_path,
        &Checkpoint {
            step: state.step as u64,
            sets: vec![
                state.params.clone(),
                state.masks.clone(),
                state.opt[0].clone(),
            ],
        },
    )?;
    println!("checkpoint written to {}", ckpt_path.display());

    // Baselines for the headline comparison.
    for (label, method) in [("Static", Method::Static), ("Dense", Method::Dense)] {
        let mut c = cfg.clone();
        c.method = method;
        c.eval_every = 0;
        let b = trainer.run(&c)?;
        println!(
            "{label:<8} acc {:.4} | trainFLOPs {:.3}x | testFLOPs {:.3}x",
            b.final_metric, b.train_flops_ratio, b.test_flops_ratio
        );
    }
    println!("\nExpected shape (paper Fig. 4-right): Static < RigL ≤ Dense at a fraction of the FLOPs.");
    Ok(())
}
