//! Appendix-B style model compression: RigL as architecture search.
//!
//!     cargo run --release --example mnist_compression
//!
//! Starts the LeNet-300-100 MLP with hand-set per-layer sparsities
//! (99%/89%, the paper's Table-2 protocol), trains with RigL on the
//! digit-blob dataset, then removes dead neurons and reports the
//! discovered compact architecture, its inference FLOPs, and its size —
//! the unstructured-sparsity counterpart to SBP/L0/VIB structured pruning.

use anyhow::Result;
use rigl::model::load_manifest;
use rigl::sparsity::Distribution;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = load_manifest(&rigl::artifacts_dir())?;

    let mut cfg = TrainConfig::new("mlp", Method::Rigl);
    cfg.distribution = Distribution::Custom(vec![0.99, 0.89]);
    cfg.steps = 600;
    cfg.delta_t = 50;
    cfg.augment = false;

    let trainer = Trainer::new(&rt, &manifest, &cfg)?;
    let mut state = trainer.init_state(&cfg);
    let r = trainer.run_from(&cfg, &mut state)?;

    // Dead-neuron removal: a hidden unit is alive iff it has both incoming
    // and outgoing active connections; an input pixel is alive iff it has
    // any outgoing connection.
    let def = &trainer.def;
    let (n_in, n_h1) = (def.specs[0].shape[0], def.specs[0].shape[1]);
    let n_h2 = def.specs[2].shape[1];
    let m1 = &state.masks.tensors[0];
    let m2 = &state.masks.tensors[2];
    let live_in = (0..n_in)
        .filter(|&r| (0..n_h1).any(|c| m1[r * n_h1 + c] != 0.0))
        .count();
    let live_h1 = (0..n_h1)
        .filter(|&h| {
            (0..n_in).any(|r| m1[r * n_h1 + h] != 0.0)
                && (0..n_h2).any(|c| m2[h * n_h2 + c] != 0.0)
        })
        .count();
    let live_h2 = (0..n_h2)
        .filter(|&h| (0..n_h1).any(|r| m2[r * n_h2 + h] != 0.0))
        .count();

    let nnz: usize = (0..def.specs.len())
        .filter(|&i| def.specs[i].sparsifiable)
        .map(|i| state.masks.nnz(i))
        .sum();
    println!("== RigL as architecture search (digit-blob MNIST stand-in) ==");
    println!("start architecture : 784-{n_h1}-{n_h2}");
    println!("found architecture : {live_in}-{live_h1}-{live_h2}");
    println!("active connections : {nnz}");
    println!("inference KFLOPs   : {:.1}", 2.0 * nnz as f64 / 1e3);
    println!("size (bytes)       : {:.0}", 4.0 * nnz as f64 + (live_in * live_h1 + live_h1 * live_h2) as f64 / 8.0);
    println!("val error          : {:.2}%", (1.0 - r.final_metric) * 100.0);
    println!("\nPaper Table-2 comparators: SBP 245-160-55 (97.1 KFLOPs), L0 266-88-33 (53.3), VIB 97-71-33 (19.1).");
    Ok(())
}
