//! Loss-landscape exploration (paper §4.4 / Fig. 6).
//!
//!     cargo run --release --example landscape
//!
//! Trains a static-sparse MLP and a pruning MLP to convergence, then walks
//! the loss surface between them: straight line, quadratic Bézier in the
//! sparse subspace, and quadratic Bézier through the full dense space —
//! showing the high-loss barrier the sparse subspace cannot avoid and the
//! near-monotone dense path that motivates dynamic topology.

use anyhow::Result;
use rigl::landscape::{barrier, linear_path, Bezier};
use rigl::model::{load_manifest, ParamSet};
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = load_manifest(&rigl::artifacts_dir())?;

    let mut cfg = TrainConfig::new("mlp", Method::Static);
    cfg.sparsity = 0.9;
    cfg.steps = 400;
    cfg.augment = false;
    let trainer = Trainer::new(&rt, &manifest, &cfg)?;

    println!("training endpoint A: static-sparse…");
    let mut sa = trainer.init_state(&cfg);
    trainer.run_from(&cfg, &mut sa)?;

    println!("training endpoint B: gradual pruning…");
    let mut cfg_p = cfg.clone();
    cfg_p.method = Method::Pruning;
    let mut sb = trainer.init_state(&cfg_p);
    trainer.run_from(&cfg_p, &mut sb)?;

    println!("\n-- linear interpolation (loss at 11 points) --");
    let lin = linear_path(&trainer, &cfg, &sa, &sb, 11, 4)?;
    for (t, l) in &lin {
        println!("t={t:.2}  loss {l:.4}");
    }

    let union = ParamSet::mask_union(&sa.masks, &sb.masks);
    println!("\noptimizing quadratic Bézier in the sparse subspace…");
    let mut qs = Bezier::new(&sa.params, &sb.params, 2);
    qs.optimize(&trainer, &cfg, Some(&union), 60, 0.05, 1)?;
    let ps = qs.profile(&trainer, &cfg, 11, 4, Some(&union))?;

    println!("optimizing quadratic Bézier in the dense space…");
    let mut qd = Bezier::new(&sa.params, &sb.params, 2);
    qd.optimize(&trainer, &cfg, None, 60, 0.05, 2)?;
    let pd = qd.profile(&trainer, &cfg, 11, 4, None)?;

    println!("\n{:<28} {:>10}", "path", "barrier");
    println!("{:<28} {:>10.4}", "linear", barrier(&lin));
    println!("{:<28} {:>10.4}", "quadratic (sparse space)", barrier(&ps));
    println!("{:<28} {:>10.4}", "quadratic (dense space)", barrier(&pd));
    println!("\nExpected shape (Fig. 6-left): sparse-space paths keep a high-loss barrier; the dense-space curve flattens it.");
    Ok(())
}
