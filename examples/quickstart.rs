//! Quickstart: train one sparse network with RigL and print the result.
//!
//!     cargo run --release --example quickstart
//!
//! Demonstrates the whole public API surface in ~30 lines: load the AOT
//! manifest, build a trainer, pick the paper-default RigL configuration,
//! run, and read the Appendix-H FLOPs accounting off the result.

use anyhow::Result;
use rigl::model::load_manifest;
use rigl::sparsity::Distribution;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::Runtime;

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let manifest = load_manifest(&rigl::artifacts_dir())?;

    // 90% sparse LeNet-300-100 with the Erdős–Rényi-Kernel distribution.
    let mut cfg = TrainConfig::new("mlp", Method::Rigl);
    cfg.sparsity = 0.9;
    cfg.distribution = Distribution::Erk;
    cfg.steps = 400;
    cfg.delta_t = 50;
    cfg.eval_every = 100;

    let trainer = Trainer::new(&rt, &manifest, &cfg)?;
    println!(
        "model mlp: {} params ({} sparsifiable), target sparsity {}",
        trainer.def.num_params(),
        trainer.def.sparsifiable_params(),
        cfg.sparsity
    );

    let r = trainer.run(&cfg)?;
    for (step, metric) in &r.eval_history {
        println!("step {step:>5}  val accuracy {metric:.4}");
    }
    println!(
        "\nfinal accuracy {:.4} at {:.1}% sparsity",
        r.final_metric,
        100.0 * r.final_sparsity
    );
    println!(
        "training cost {:.3}x dense, inference cost {:.3}x dense ({} connections rewired)",
        r.train_flops_ratio, r.test_flops_ratio, r.total_swapped
    );
    Ok(())
}
