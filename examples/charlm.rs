//! Character-level language modeling (paper §4.2): sparse GRU on the
//! Markov corpus, comparing RigL against SET and Static at 75% sparsity.
//!
//!     cargo run --release --example charlm [steps]
//!
//! Reports validation bits/char next to the corpus's analytic entropy
//! floor, reproducing the Fig. 4-left ordering (Static < SET < RigL).

use anyhow::Result;
use rigl::data::CharDataset;
use rigl::model::load_manifest;
use rigl::topology::Method;
use rigl::train::{TrainConfig, Trainer};
use rigl::Runtime;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(400);
    let rt = Runtime::cpu()?;
    let manifest = load_manifest(&rigl::artifacts_dir())?;

    let corpus = CharDataset::synth(20_000, 64, 2.0, 0xDA7A);
    println!(
        "Markov corpus: 64 symbols, analytic entropy {:.3} bits/char (uniform = 6.000)",
        corpus.entropy_bits
    );

    for (label, method) in [
        ("Dense", Method::Dense),
        ("Static", Method::Static),
        ("SET", Method::Set),
        ("RigL", Method::Rigl),
    ] {
        let mut cfg = TrainConfig::new("gru", method);
        cfg.sparsity = 0.75;
        cfg.steps = steps;
        cfg.delta_t = (steps / 10).max(10);
        cfg.alpha = 0.1; // paper Appendix I
        cfg.t_end_frac = 1.0;
        let trainer = Trainer::new(&rt, &manifest, &cfg)?;
        let r = trainer.run(&cfg)?;
        println!(
            "{label:<8} bits/char {:.4} | trainFLOPs {:.3}x | S={:.3}",
            r.final_metric, r.train_flops_ratio, r.final_sparsity
        );
    }
    Ok(())
}
